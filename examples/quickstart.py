"""Quickstart: train a small LM end-to-end with the full framework stack —
prefetching data pipeline (advancedload), async checkpointing
(delegatestore), auto-resume, then serve a few tokens from the trained
weights.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_config, reduced  # noqa: E402
from repro.launch.serve import serve  # noqa: E402
from repro.launch.train import train  # noqa: E402


def main():
    cfg = reduced(get_config("internlm2-20b"))
    print(f"config: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train(cfg, steps=60, batch=8, seq=64, ckpt_dir=ckpt_dir,
                    ckpt_every=20, log_every=10)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\ntrained {out['final_step']} steps: "
          f"loss {first:.3f} -> {last:.3f} "
          f"({out['wall_s']:.1f}s wall)")
    assert last < first, "loss should decrease on the learnable stream"

    res = serve(cfg, batch=2, prompt_len=16, gen=8)
    print(f"served: {res['generated'].shape[1]} tokens/request, "
          f"{res['tokens_per_s']:.0f} tok/s")
    print("sample tokens:", res["generated"][0])


if __name__ == "__main__":
    main()
