"""Batched serving demo: prefill + greedy decode for three architecture
families (dense GQA, Griffin hybrid, RWKV-6), showing the per-family cache
kinds (full KV / ring buffer + recurrent state / constant-size state).

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.launch.serve import serve  # noqa: E402
from repro.models import Transformer  # noqa: E402


def cache_report(cfg):
    m = Transformer(reduced(cfg) if cfg.n_layers > 4 else cfg)
    cache = jax.eval_shape(lambda: m.init_cache(2, 64))
    leaves = jax.tree.leaves(cache)
    total = sum(int(x.size) * x.dtype.itemsize for x in leaves)
    return f"{len(leaves)} buffers, {total / 1024:.0f} KiB at (B=2, T=64)"


def main():
    for name in ("internlm2-20b", "recurrentgemma-2b", "rwkv6-3b"):
        cfg = reduced(get_config(name))
        print(f"\n=== {name} [{get_config(name).family}] ===")
        print("cache:", cache_report(get_config(name)))
        out = serve(cfg, batch=4, prompt_len=16, gen=12)
        print(f"prefill {out['prefill_s']:.2f}s, decode "
              f"{out['decode_s']:.2f}s ({out['tokens_per_s']:.0f} tok/s)")
        print("tokens[0]:", out["generated"][0])


if __name__ == "__main__":
    main()
