"""The paper's technique applied to a training loop: the miniature
train-loop block-program is planned (batch upload hoisted, weights and
optimizer state device-resident with noupdate, loss fetched once at the
end), the generated schedule is printed, and both plans are executed with
instrumented transfers.

    PYTHONPATH=src python examples/offload_pipeline.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import emit, execute, naive_plan, plan  # noqa: E402
from repro.optim import plan_step_program  # noqa: E402


def main():
    prog = plan_step_program(n_steps=6)
    optimized = plan(prog)
    print(emit(optimized))

    _, s_opt = execute(optimized)
    _, s_nv = execute(naive_plan(prog))
    print(f"\noptimized: {s_opt.h2d_transfers} uploads / "
          f"{s_opt.d2h_transfers} downloads")
    print(f"naive:     {s_nv.h2d_transfers} uploads / "
          f"{s_nv.d2h_transfers} downloads")
    print("\nthe residency win: weights + optimizer state stay on device "
          f"across all 6 steps ({s_nv.h2d_transfers - s_opt.h2d_transfers} "
          "uploads elided)")


if __name__ == "__main__":
    main()
