"""The paper's worked example (Tables 1-2): 3MM through the OMP2HMPP
planner.  Prints the generated HMPP-style source, then executes the
optimized and naive plans and compares the transfer schedules.

    PYTHONPATH=src python examples/polybench_3mm.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np        # noqa: E402

from repro.core import (emit, execute, naive_plan, plan,  # noqa: E402
                        run_host_oracle)
from repro.polybench import build                         # noqa: E402


def main():
    p, _ = build("3mm", n=256)

    optimized = plan(p)
    print("=" * 70)
    print("GENERATED CODE (paper Table 2 analogue)")
    print("=" * 70)
    print(emit(optimized))

    out_opt, s_opt = execute(optimized)
    out_nv, s_nv = execute(naive_plan(p))
    oracle = run_host_oracle(p)
    assert np.allclose(out_opt["out"], oracle["out"], rtol=1e-3)
    assert np.allclose(out_nv["out"], oracle["out"], rtol=1e-3)

    print("\ntransfer schedule comparison:")
    print(f"  {'':>12s} {'optimized':>10s} {'naive':>10s}")
    print(f"  {'h2d count':>12s} {s_opt.h2d_transfers:>10d} "
          f"{s_nv.h2d_transfers:>10d}")
    print(f"  {'d2h count':>12s} {s_opt.d2h_transfers:>10d} "
          f"{s_nv.d2h_transfers:>10d}")
    print(f"  {'bytes moved':>12s} "
          f"{(s_opt.h2d_bytes + s_opt.d2h_bytes) // 2**20:>9d}M "
          f"{(s_nv.h2d_bytes + s_nv.d2h_bytes) // 2**20:>9d}M")
    print("\nresults match the pure-host oracle ✓")


if __name__ == "__main__":
    main()
