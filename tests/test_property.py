"""Property-based tests (hypothesis): random block programs through the
planner/executor must satisfy the system invariants:

  1. execute(optimized) == execute(naive) == pure-host oracle
  2. transfers(optimized) ≤ transfers(naive)  (counts, per category)
  3. plans are valid: the checking executor raises on any read from a
     space without a valid copy — so mere successful execution is the
     validity proof.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Program, execute, naive_plan,  # noqa: E402
                        plan, run_host_oracle)

VARS = ["a", "b", "c", "d", "e"]


def _mk_op(kind):
    if kind == 0:
        return lambda xp, x: {"_": x * 1.5 + 0.25}
    if kind == 1:
        return lambda xp, x: {"_": xp.tanh(x)}
    return lambda xp, x, y: {"_": x + 0.5 * y}


@st.composite
def programs(draw):
    n_blocks = draw(st.integers(2, 7))
    p = Program("prop")
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    n_init = draw(st.integers(1, 3))
    live = VARS[:n_init]
    for v in live:
        p.bind(v, rng.standard_normal(8).astype(np.float32))
    loop_open = False
    for i in range(n_blocks):
        # maybe open/close a single-level loop
        action = draw(st.integers(0, 5))
        if not loop_open and action == 0:
            ctx = p.loop(draw(st.integers(2, 4)))
            ctx.__enter__()
            loop_open = True
            ctx_obj = ctx
        elif loop_open and action == 1:
            ctx_obj.__exit__(None, None, None)
            loop_open = False
        kind = draw(st.integers(0, 2))
        fn = _mk_op(kind)
        n_in = 2 if kind == 2 else 1
        reads = tuple(draw(st.sampled_from(live)) for _ in range(n_in))
        if kind == 2 and reads[0] == reads[1]:
            reads = (reads[0],)
            fn = _mk_op(0)
        write = draw(st.sampled_from(VARS))
        host = draw(st.booleans())

        def wrapped(xp, __fn=fn, __names=reads, **kw):
            vals = [kw[n] for n in __names]
            if len(vals) == 1:
                return {"_": __fn(xp, vals[0])["_"]}
            return {"_": __fn(xp, *vals)["_"]}

        def named(xp, __w=write, __wrapped=wrapped, **kw):
            return {__w: __wrapped(xp, **kw)["_"]}

        if host:
            p.host(named, reads=reads, writes=(write,), name=f"h{i}")
        else:
            p.offload(named, reads=reads, writes=(write,), name=f"k{i}")
        if write not in live:
            live.append(write)
    if loop_open:
        ctx_obj.__exit__(None, None, None)
    p.set_outputs(*live)
    return p


@settings(max_examples=60, deadline=None)
@given(programs())
def test_optimized_equals_naive_equals_oracle(p):
    oracle = run_host_oracle(p)
    out_opt, s_opt = execute(plan(p))          # check=True validates plan
    out_nv, s_nv = execute(naive_plan(p))
    # output contract: every runner returns exactly program.outputs
    assert set(oracle) == set(out_opt) == set(out_nv) == set(p.outputs)
    for k in p.outputs:
        np.testing.assert_allclose(out_opt[k], oracle[k], rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(out_nv[k], oracle[k], rtol=1e-5,
                                   atol=1e-5)
    assert s_opt.h2d_transfers <= s_nv.h2d_transfers
    assert s_opt.d2h_transfers <= s_nv.d2h_transfers


@settings(max_examples=30, deadline=None)
@given(programs())
def test_transfer_bytes_monotone(p):
    _, s_opt = execute(plan(p))
    _, s_nv = execute(naive_plan(p))
    assert s_opt.h2d_bytes <= s_nv.h2d_bytes
    assert s_opt.d2h_bytes <= s_nv.d2h_bytes
