"""Backend/stream runtime + compiled execution path.

The contract under test (ISSUE 1 tentpole):

  * ``execute(plan, mode="compiled")`` is bitwise-identical to
    ``mode="interpreted"`` on the same backend, and matches the pure-host
    oracle to the usual float tolerance,
  * the *logical* ``ExecStats`` transfer counts are mode-invariant,
  * the compiled path actually fuses (fewer jit entries than logical
    kernel calls where segments hold several blocks),
  * every registered backend honors the residency discipline.
"""
import numpy as np
import pytest

from repro.core import (JaxDeviceBackend, NumpyHostBackend,
                        PinnedHostBackend, PlanExecutionError, Synchronize,
                        compile_plan, execute, get_backend, naive_plan,
                        plan, run_host_oracle)
from repro.core.ir import AdvancedLoad, Program
from repro.optim import plan_step_program
from repro.polybench import build_3mm


def _modes_equal(p, planner=plan, backend=None):
    pl = planner(p)
    out_i, s_i = execute(pl, mode="interpreted", backend=backend)
    out_c, s_c = execute(pl, mode="compiled", backend=backend)
    for k in p.outputs:
        np.testing.assert_array_equal(
            out_i[k], out_c[k],
            err_msg=f"compiled vs interpreted mismatch for {k!r}")
    assert s_i.transfer_counts() == s_c.transfer_counts()
    return out_c, s_i, s_c


class TestCompiledEquivalence:
    def test_train_step_program(self):
        p = plan_step_program(n_steps=4)
        out, _, _ = _modes_equal(p)
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out["w"], oracle["w"], rtol=1e-5)
        np.testing.assert_allclose(out["final_loss"], oracle["final_loss"],
                                   rtol=1e-5)

    def test_train_step_program_naive(self):
        _modes_equal(plan_step_program(n_steps=3), planner=naive_plan)

    def test_polybench_3mm(self):
        p, _ = build_3mm(n=48)
        out, _, _ = _modes_equal(p)
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out["out"], oracle["out"], rtol=2e-3,
                                   atol=1e-3)

    def test_polybench_3mm_naive(self):
        p, _ = build_3mm(n=48)
        _modes_equal(p, planner=naive_plan)

    def test_transfer_count_parity_and_fusion(self):
        """Counts are the plan's logical schedule (mode-invariant); the
        compiled path executes it in fewer jit entries."""
        p, _ = build_3mm(n=32)
        _, s_i, s_c = _modes_equal(p)
        assert s_i.fused_launches == 0
        # 3mm's three matmuls sit in one group with no host block between
        # them: one fused launch for three logical kernel calls
        assert s_c.kernel_calls == 3
        assert s_c.fused_launches == 1

    def test_loop_body_lowered_whole(self):
        """A pure-device loop body rolls into ONE fused launch for the
        whole loop (lax.fori_loop): kernel_calls still scales with trip
        count (logical parity with the interpreter) while fused_launches
        counts a single dispatch."""
        from repro.polybench import build
        p, _ = build("gemm", n=32, iters=5)
        _, s_i, s_c = _modes_equal(p)
        assert s_c.kernel_calls == 5
        assert s_c.fused_launches == 1

    def test_loop_fusion_can_be_disabled(self):
        """fuse_loops=False keeps the PR-1 per-iteration segment path:
        one fused launch per iteration, same outputs."""
        from repro.polybench import build
        p, _ = build("gemm", n=32, iters=5)
        pl = plan(p)
        out_f, s_f = execute(pl, mode="compiled")
        out_n, s_n = execute(pl, mode="compiled", fuse_loops=False)
        for k in p.outputs:
            np.testing.assert_array_equal(out_f[k], out_n[k])
        assert s_f.fused_launches == 1
        assert s_n.fused_launches == 5
        assert s_f.transfer_counts() == s_n.transfer_counts()

    def test_compiled_mode_checks_residency(self):
        """A hand-broken plan (load removed) still raises."""
        p, _ = build_3mm(n=16)
        pl = plan(p)
        drop = next(op for op in pl.ops
                    if op.kind == "directive"
                    and isinstance(op.directive, AdvancedLoad))
        pl.ops.remove(drop)
        with pytest.raises(PlanExecutionError):
            execute(pl, mode="compiled")

    def test_unknown_mode_rejected(self):
        p, _ = build_3mm(n=16)
        with pytest.raises(ValueError):
            execute(plan(p), mode="eager")


class TestBackends:
    @pytest.mark.parametrize("name", ["numpy", "jax", "pinned"])
    def test_all_backends_run_both_modes(self, name):
        be = get_backend(name)
        p = plan_step_program(n_steps=2)
        out_i, s_i = execute(plan(p), mode="interpreted", backend=be)
        out_c, s_c = execute(plan(p), mode="compiled", backend=be)
        for k in p.outputs:
            np.testing.assert_array_equal(out_i[k], out_c[k])
        assert s_i.transfer_counts() == s_c.transfer_counts()
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out_c["w"], oracle["w"], rtol=1e-5)

    def test_numpy_backend_is_exact_vs_oracle(self):
        """Pure-host backend: block bodies run under numpy, so results are
        bitwise-equal to the oracle, not just close."""
        p = plan_step_program(n_steps=3)
        out, _ = execute(plan(p), backend=NumpyHostBackend())
        oracle = run_host_oracle(p)
        np.testing.assert_array_equal(out["w"], oracle["w"])

    def test_stream_events_make_sync_a_wait_point(self):
        """Uploads enqueue events on their directive's stream; sync drains
        exactly that stream."""
        be = JaxDeviceBackend()
        h = be.upload(np.ones((8, 8), np.float32), stream=1)
        assert be._pending  # event recorded
        be.sync(1)
        assert not any(be._pending.values())
        h.block_until_ready()

    def test_planner_assigns_streams_per_group(self):
        p, _ = build_3mm(n=16)
        pl = plan(p)
        loads = pl.directives(AdvancedLoad)
        syncs = pl.directives(Synchronize)
        assert loads and all(d.stream >= 1 for d in loads)
        assert syncs and all(d.stream >= 1 for d in syncs)
        # one group -> one transfer stream shared by its transfers
        assert len({d.stream for d in loads}) == 1

    def test_pinned_backend_degrades_on_cpu(self):
        """On platforms without a pinned_host space the pinned backend is
        still a correct JaxDeviceBackend."""
        be = PinnedHostBackend()
        x = np.arange(16, dtype=np.float32)
        h = be.upload(x, stream=1)
        be.sync(1)
        np.testing.assert_array_equal(be.download(h), x)

    def test_get_backend_memoizes_by_name(self):
        assert get_backend() is get_backend()
        assert get_backend() is get_backend("jax")
        assert get_backend("numpy") is get_backend("numpy")
        with pytest.raises(ValueError):
            get_backend("cuda-streams")

    def test_free_retires_pending_events(self):
        """release of an in-flight buffer must not poison a later sync
        (the default backend is shared process-wide)."""
        from repro.core import DeviceResidency
        rd = DeviceResidency()
        rd.put_host("x", np.ones((4, 4), np.float32))
        rd.prefetch("x")
        rd.release("x")
        rd.wait()    # must not raise on the deleted buffer
        p, _ = build_3mm(n=8)
        execute(plan(p))   # shared default backend still healthy

    def test_compile_plan_reused_across_executions(self):
        """execute(mode="compiled") caches the lowering on the plan, so
        repeated runs (the benchmark loop) skip re-lowering."""
        p, _ = build_3mm(n=16)
        pl = plan(p)
        execute(pl, mode="compiled")
        first, _ = pl.meta["_compiled"]["jax"]
        execute(pl, mode="compiled")
        assert pl.meta["_compiled"]["jax"][0] is first

    def test_compiled_cache_invalidated_on_plan_mutation(self):
        """Mutating plan.ops after a compiled run must re-lower, keeping
        count parity with the interpreter for the mutated plan."""
        p, _ = build_3mm(n=16)
        pl = naive_plan(p)
        _, s0 = execute(pl, mode="compiled")
        drop = next(op for op in pl.ops
                    if op.kind == "directive"
                    and isinstance(op.directive, Synchronize))
        pl.ops.remove(drop)
        _, s1 = execute(pl, mode="compiled")
        assert s1.syncs == s0.syncs - 1
        _, s_i = execute(pl, mode="interpreted")
        assert s1.transfer_counts() == s_i.transfer_counts()

    def test_emitter_shows_stream_attribute(self):
        from repro.core import emit
        p, _ = build_3mm(n=16)
        text = emit(plan(p))
        assert "stream=" in text
        assert "asynchronous" in text


class TestHazardSplit:
    def test_store_then_load_same_var_splits_segment(self):
        """An upload after an in-segment download of the same variable must
        observe the downloaded host value — the naive 3mm plan hits this
        (E stored after mm_E, loaded again at mm_G) and stays correct."""
        p, _ = build_3mm(n=24)
        pl = naive_plan(p)
        compiled = compile_plan(pl, get_backend())
        segs = [item for item in compiled.schedule if item[0] == "seg"]
        with_blocks = [s for _, s in segs if s.blocks]
        assert len(with_blocks) >= 2   # split at the store->load hazard
        _modes_equal(p, planner=naive_plan)

    def test_load_after_device_write_raises_in_both_modes(self):
        """An upload whose var a block just wrote (host copy stale) is
        rejected by the interpreter — the compiled path must split the
        segment and reject it identically, not upload stale data."""
        from repro.core import PlanOp
        p, _ = build_3mm(n=16)
        pl = plan(p)
        blk_pos = next(i for i, op in enumerate(pl.ops)
                       if op.kind == "block"
                       and p.blocks[op.block_idx].writes == ("E",))
        bad = PlanOp("directive",
                     directive=AdvancedLoad(var="E", group=0, stream=1))
        pl.ops.insert(blk_pos + 1, bad)
        with pytest.raises(PlanExecutionError):
            execute(pl, mode="interpreted")
        with pytest.raises(PlanExecutionError):
            execute(pl, mode="compiled")

    def test_host_write_inside_loop(self):
        """Host block inside the kernel loop: per-iteration upload in both
        modes, identical results."""
        p = Program()
        p.bind("A", np.ones((8,), np.float32))
        with p.loop(4):
            p.host(lambda xp, A: {"A": A + 1.0}, reads=("A",),
                   writes=("A",), name="w")
            p.offload(lambda xp, A: {"B": A * 2.0}, reads=("A",),
                      writes=("B",), name="k")
        p.host(lambda xp, B: {"o": B}, reads=("B",), writes=("o",),
               name="c")
        p.set_outputs("o")
        _, s_i, s_c = _modes_equal(p)
        assert s_c.h2d_transfers == 4
