"""ISSUE 9 end-to-end: mesh-aware plan generation on forced fake devices.

Each test runs in a subprocess with ``--xla_force_host_platform_device_count``
so the main pytest process keeps its single CPU device.  Tier-1 deselects
this file (like the distributed subprocess tests); the dedicated CI mesh
job runs it under 8 fake devices.

The measured sharded-beats-single-device gate is ADAPTIVE: 8 fake CPU
devices time-slice the host's cores, so sharding can only win wall-clock
when there is real parallel silicon underneath.  With >= 2 cores
(the CI runners) the gate is strict; on a 1-core host the test still
requires the tuner to *select* a sharded placement, beat the
replicated-on-mesh baseline, and verify + cache-roundtrip cleanly.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_py(body: str, n_devices: int = 8, timeout=560, env=None):
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(body))
    # JAX_PLATFORMS=cpu: without it jax probes for a TPU backend first
    # (minutes of metadata-server retries on a non-TPU host)
    full_env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
                "JAX_PLATFORMS": "cpu"}
    full_env.update(env or {})
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=full_env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_mesh_tune_selects_sharded_plan_and_caches(tmp_path):
    """The acceptance gate: plan(p, policy="auto") on the mesh backend
    picks a sharded placement, the winner verifies clean, beats the
    replicated-on-mesh plan, beats the single-device plan when the host
    has parallel cores, and the warm cache answers the repeat call with
    zero measurements."""
    out = run_py("""
        import json, os
        from repro.polybench import build
        from repro.core import plan, execute, run_host_oracle, verify_plan
        from repro.core.backend import get_backend
        import numpy as np

        p, _ = build("3mm", n=256)
        be = get_backend("mesh")
        assert be.n_devices == 8, be.mesh_desc

        tuned = plan(p, policy="auto", backend=be, reps=1)
        tuning = tuned.meta["tuning"]
        mesh_rec = tuned.meta.get("mesh")
        assert mesh_rec is not None, tuning["chosen"]
        assert mesh_rec["placement"] in ("fsdp", "tp"), mesh_rec
        assert any(e for e in mesh_rec["specs"].values()
                   if any(x is not None for x in e)), mesh_rec

        # the winner verifies clean (collective = sync point, no gaps)
        rep = verify_plan(tuned)
        assert rep.ok, rep.summary()

        # sharded winner beats the replicated-on-mesh plan, measured
        meas = [c for c in tuning["candidates"]
                if c["valid"] and c.get("measured_s") is not None]
        chosen = next(c for c in meas if c["label"] == tuning["chosen"])
        repl = [c for c in meas
                if c["config"]["mesh_placement"] == "replicate"]
        assert repl and chosen["measured_s"] <= min(
            c["measured_s"] for c in repl), (
            chosen["measured_s"], min(c["measured_s"] for c in repl))

        # kernel_s residuals recorded for every measured candidate
        assert all(c.get("measured_kernel_s") is not None
                   and c.get("kernel_residual_s") is not None
                   for c in meas)

        # the sharded plan executes correctly through the mesh backend
        out_m, _ = execute(tuned, backend=be)
        oracle = run_host_oracle(p)
        # sharded reductions reassociate the accumulation: tolerance
        # covers the collective's summation-order drift, nothing more
        np.testing.assert_allclose(np.asarray(out_m["out"]), oracle["out"],
                                   rtol=2e-3)

        # single-device comparison
        p1, _ = build("3mm", n=256)
        single = plan(p1, policy="auto", backend="jax", reps=1)
        s_meas = min(c["measured_s"]
                     for c in single.meta["tuning"]["candidates"]
                     if c["valid"] and c.get("measured_s") is not None)
        n_cores = len(os.sched_getaffinity(0))
        ratio = s_meas / chosen["measured_s"]
        print("RATIO", json.dumps({"cores": n_cores, "ratio": ratio}))
        if n_cores >= 2:
            assert ratio > 1.0, (
                f"sharded plan must beat single-device on {n_cores} "
                f"cores: {chosen['measured_s']} vs {s_meas}")

        # warm cache: repeat call answers with zero measurements
        p2, _ = build("3mm", n=256)
        tuned2 = plan(p2, policy="auto", backend=be, reps=1)
        ci = tuned2.meta["tuning_cache"]
        assert ci["hit"] is True and ci["measurements"] == 0, ci
        assert tuned2.meta.get("mesh") == mesh_rec
        print("MESH_TUNE_OK")
    """, env={"REPRO_TUNE_CACHE": str(tmp_path / "tc")})
    assert "MESH_TUNE_OK" in out
    info = json.loads(out.split("RATIO", 1)[1].splitlines()[0])
    assert info["ratio"] > 0


def test_mesh_fingerprint_separates_mesh_shapes(tmp_path):
    """The same program tuned on a 2x4 and a 1x8 mesh must not alias in
    the tunecache (mesh shape is part of the backend fingerprint)."""
    out = run_py("""
        from repro.polybench import build
        from repro.core import plan
        from repro.distributed.mesh_backend import MeshBackend
        from repro.core.tunecache import backend_fingerprint

        be_a = MeshBackend(shape=(2, 4))
        be_b = MeshBackend(shape=(1, 8))
        assert backend_fingerprint(be_a) != backend_fingerprint(be_b)

        p, _ = build("gemm", n=64, iters=2)
        pl_a = plan(p, policy="auto", backend=be_a, reps=1)
        assert pl_a.meta["tuning_cache"]["hit"] is False
        p2, _ = build("gemm", n=64, iters=2)
        pl_b = plan(p2, policy="auto", backend=be_b, reps=1)
        assert pl_b.meta["tuning_cache"]["hit"] is False   # no aliasing
        p3, _ = build("gemm", n=64, iters=2)
        pl_a2 = plan(p3, policy="auto", backend=MeshBackend(shape=(2, 4)),
                     reps=1)
        assert pl_a2.meta["tuning_cache"]["hit"] is True   # same mesh hits
        print("MESH_FP_OK")
    """, env={"REPRO_TUNE_CACHE": str(tmp_path / "tc")})
    assert "MESH_FP_OK" in out


def test_16way_model_axis_specs_all_jit_valid():
    """Satellite: qwen2.5's 40 q-heads and arctic's 56-way dim on a
    16-way model axis stay unsharded with the drop recorded, and every
    PartitionSpec placement_specs produces actually jits."""
    out = run_py("""
        import jax, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        from repro.distributed.mesh_backend import placement_specs
        from repro.configs import get_config

        devs = jax.devices()
        assert len(devs) == 16
        mesh = Mesh(np.asarray(devs).reshape(1, 16), ("data", "model"))
        q = get_config("qwen2.5-14b")
        a = get_config("arctic-480b")
        assert q.n_heads == 40 and a.n_heads == 56
        shapes = {
            "w_q": jax.ShapeDtypeStruct((q.d_model, q.n_heads * q.d_head),
                                        np.float32),
            "heads40": jax.ShapeDtypeStruct((128, q.n_heads), np.float32),
            "heads56": jax.ShapeDtypeStruct((64, a.n_heads), np.float32),
            "experts128": jax.ShapeDtypeStruct((64, a.n_experts),
                                               np.float32),
            "scalar": jax.ShapeDtypeStruct((), np.float32),
        }
        for policy in ("replicate", "fsdp", "tp"):
            specs, dropped = placement_specs(shapes, mesh, policy)
            assert set(specs) == set(shapes)       # no placement gaps
            if policy == "tp":
                # 40 % 16 and 56 % 16 != 0: the dim stays unsharded
                assert specs["heads40"][-1] is None
                assert specs["heads56"][-1] is None
                assert specs["experts128"][-1] == "model"  # 128 shards
                dropped_vars = {d[0] for d in dropped}
                assert {"heads40", "heads56"} <= dropped_vars
            # every spec jit-compiles with in_shardings on this mesh
            # (one lowering per policy: all vars as one argument list)
            order = sorted(specs)
            shs = [NamedSharding(mesh, PartitionSpec(*specs[v]))
                   for v in order]
            fn = jax.jit(lambda *xs: xs, in_shardings=shs)
            fn.lower(*[shapes[v] for v in order]).compile()
        print("JIT_VALID_OK")
    """, n_devices=16)
    assert "JIT_VALID_OK" in out
