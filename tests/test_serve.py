"""ISSUE 8: continuous-batching serving engine.

Covers the serve package bottom-up — request lifecycle legality, the
admission queue's policies and token budget, KV-slot pool churn /
bit-reuse / leak detection — then the engine end to end: token-exact
equality against a per-request reference decode (padded buckets on a
dense arch, exact buckets on rwkv), the gen=1 degenerate case, the
static-join baseline, over-capacity queueing, donation defaults, and
the shape-bucket → persistent tunecache mapping (warm runs measure
nothing).
"""
import math

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.serve import (AdmissionQueue, ContinuousBatcher, Engine,
                         KVSlotPool, Request, RequestState, ServeRuntime,
                         bucket_len, cache_bytes_per_slot, make_trace)

MAX_SEQ = 48


def _tokens(L, seed=0):
    return np.random.default_rng(seed).integers(0, 257, (L,)).astype(np.int32)


def _req(rid, L=8, gen=4, arrival=0.0, seed=None):
    return Request(rid=rid, prompt=_tokens(L, seed if seed is not None
                                           else rid),
                   max_new_tokens=gen, arrival_s=arrival)


# ---------------------------------------------------------------------------
# Request lifecycle
# ---------------------------------------------------------------------------

class TestRequestLifecycle:
    def test_legal_path(self):
        r = _req(0, gen=2)
        assert r.state is RequestState.QUEUED
        r.to_prefilling(0.1)
        r.to_decoding(slot=3, now=0.2)
        r.to_finished(0.5)
        r.retire(np.zeros((2,), np.int32))
        assert r.slot == 3 and r.latency_s == pytest.approx(0.5)

    def test_illegal_transitions_raise(self):
        r = _req(0)
        with pytest.raises(RuntimeError, match="illegal transition"):
            r.to_decoding(slot=0, now=0.0)       # must prefill first
        r.to_prefilling(0.0)
        with pytest.raises(RuntimeError, match="illegal transition"):
            r.to_finished(0.0)                   # must decode first

    def test_validation(self):
        with pytest.raises(ValueError, match="max_new_tokens"):
            _req(0, gen=0)
        with pytest.raises(ValueError, match="prompt"):
            Request(rid=0, prompt=np.zeros((0,), np.int32), max_new_tokens=1)

    def test_total_tokens(self):
        assert _req(0, L=8, gen=4).total_tokens == 12


# ---------------------------------------------------------------------------
# Admission queue
# ---------------------------------------------------------------------------

class TestAdmissionQueue:
    def test_fcfs_order(self):
        q = AdmissionQueue("fcfs")
        for rid, L in enumerate((24, 8, 16)):
            q.push(_req(rid, L=L))
        got = q.pop_admissible(3, 0)
        assert [r.rid for r in got] == [0, 1, 2]

    def test_sjf_prefers_short_prompts(self):
        q = AdmissionQueue("sjf")
        for rid, L in enumerate((24, 8, 16)):
            q.push(_req(rid, L=L))
        got = q.pop_admissible(2, 0)
        assert [r.prompt_len for r in got] == [8, 16]
        assert len(q) == 1                       # long one waits, not dropped

    def test_budget_blocks_in_order(self):
        q = AdmissionQueue("fcfs", max_batch_tokens=30)
        q.push(_req(0, L=8, gen=4))   # 12
        q.push(_req(1, L=20, gen=4))  # 24: 12+24 > 30 -> blocks
        q.push(_req(2, L=8, gen=4))   # behind the blocked one: waits too
        got = q.pop_admissible(3, 0)
        assert [r.rid for r in got] == [0]
        assert len(q) == 2
        s = q.stats()
        assert s["arrived"] == 3 and s["peak_depth"] == 3

    def test_slot_bound(self):
        q = AdmissionQueue("fcfs")
        for rid in range(4):
            q.push(_req(rid))
        assert len(q.pop_admissible(2, 0)) == 2

    def test_unknown_policy(self):
        with pytest.raises(ValueError, match="policy"):
            AdmissionQueue("priority")


# ---------------------------------------------------------------------------
# KV-slot pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rwkv_cfg():
    return reduced(get_config("rwkv6-3b"))


@pytest.fixture(scope="module")
def dense_cfg():
    return reduced(get_config("qwen2.5-14b"))


class TestKVSlotPool:
    def test_churn_never_exceeds_capacity(self, rwkv_cfg):
        from repro.models import Transformer
        pool = KVSlotPool(Transformer(rwkv_cfg), capacity=3, max_seq=16)
        held = []
        for i in range(50):
            s = pool.alloc()
            if s is None:
                assert pool.in_use == 3
                pool.free(held.pop(0))
            else:
                held.append(s)
            assert pool.in_use <= 3
        for s in held:
            pool.free(s)
        pool.assert_no_leaks()
        assert pool.stats()["peak_in_use"] == 3
        assert pool.stats()["reused_slots"] > 0   # churn recycled indices

    def test_lifo_bit_reuse(self, rwkv_cfg):
        from repro.models import Transformer
        pool = KVSlotPool(Transformer(rwkv_cfg), capacity=4, max_seq=16)
        a, b = pool.alloc(), pool.alloc()
        pool.free(b)
        assert pool.alloc() == b     # the just-freed slot comes back first
        pool.free(a)
        assert pool.alloc() == a

    def test_double_free_raises(self, rwkv_cfg):
        from repro.models import Transformer
        pool = KVSlotPool(Transformer(rwkv_cfg), capacity=2, max_seq=16)
        s = pool.alloc()
        pool.free(s)
        with pytest.raises(RuntimeError, match="double free"):
            pool.free(s)

    def test_leak_detection(self, rwkv_cfg):
        from repro.models import Transformer
        pool = KVSlotPool(Transformer(rwkv_cfg), capacity=2, max_seq=16)
        pool.alloc()
        with pytest.raises(RuntimeError, match="leak"):
            pool.assert_no_leaks()

    def test_insert_requires_allocated_slot(self, rwkv_cfg):
        from repro.models import Transformer
        m = Transformer(rwkv_cfg)
        pool = KVSlotPool(m, capacity=2, max_seq=16)
        with pytest.raises(RuntimeError, match="unallocated"):
            pool.insert(m.init_cache(1, 16), 0, 0)

    def test_batch_axis_inference_griffin(self):
        """Griffin's cache mixes (periods, 2, B, ...) recurrent leaves
        with (periods, B, W, ...) ring-buffer leaves — the inferred axis
        must differ per leaf, not be assumed constant."""
        from repro.models import Transformer
        cfg = reduced(get_config("recurrentgemma-2b"))
        pool = KVSlotPool(Transformer(cfg), capacity=2, max_seq=16)
        assert len(set(pool.batch_axes)) > 1

    def test_bytes_per_slot_positive(self, rwkv_cfg):
        from repro.models import Transformer
        assert cache_bytes_per_slot(Transformer(rwkv_cfg), 16) > 0


# ---------------------------------------------------------------------------
# Batcher
# ---------------------------------------------------------------------------

class TestBatcher:
    def test_continuous_joins_any_time(self):
        b = ContinuousBatcher("continuous")
        b.join(_req(0, gen=3), 0)
        assert b.can_join()

    def test_static_joins_only_when_empty(self):
        b = ContinuousBatcher("static")
        assert b.can_join()
        b.join(_req(0, gen=3), 0)
        assert not b.can_join()
        b.step(); b.step()
        assert b.leave(0).rid == 0
        assert b.can_join()

    def test_step_counts_down(self):
        b = ContinuousBatcher()
        b.join(_req(0, gen=3), 0)
        b.join(_req(1, gen=1), 1)
        assert b.finished_now() == [1]           # gen=1: done pre-decode
        b.leave(1)
        assert b.step() == [] and b.step() == [0]


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------

def test_bucket_len():
    assert bucket_len(3, 64, exact=False) == 8       # floor
    assert bucket_len(9, 64, exact=False) == 16      # next pow2
    assert bucket_len(16, 64, exact=False) == 16     # exact pow2 kept
    assert bucket_len(60, 64, exact=False) == 64     # capped at max_seq
    assert bucket_len(13, 64, exact=True) == 13      # recurrent: exact


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------

def _reference_decode(rt, req):
    """Per-request greedy decode straight through the model — the
    launch.serve loop at batch=1, no padding, no pooling."""
    import jax
    import jax.numpy as jnp
    cfg, model, params = rt.cfg, rt.model, rt.params
    if cfg.input_embeds:
        batch = {"embeds": jnp.asarray(req.prompt[None])}
    else:
        batch = {"tokens": jnp.asarray(req.prompt[None])}
    logits, cache = model.prefill(params, batch, max_seq=rt.max_seq)
    decode = jax.jit(model.decode_step)
    if cfg.n_codebooks:
        logits = logits[..., 0, :]
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [int(tok[0])]
    for i in range(req.max_new_tokens - 1):
        pos = jnp.full((1,), req.prompt_len + i, jnp.int32)
        step = ({"embeds": jnp.zeros((1, cfg.d_model), jnp.float32)}
                if cfg.input_embeds else {"tokens": tok})
        logits, cache = decode(params, cache, step, pos)
        if cfg.n_codebooks:
            logits = logits[..., 0, :]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return np.array(out, np.int32)


@pytest.fixture(scope="module")
def rwkv_rt(rwkv_cfg):
    rt = ServeRuntime(rwkv_cfg, max_seq=MAX_SEQ, seed=0)
    rt.tune = None     # per-test cache isolation is function-scoped
    return rt


@pytest.fixture(scope="module")
def dense_rt(dense_cfg):
    rt = ServeRuntime(dense_cfg, max_seq=MAX_SEQ, seed=0)
    rt.tune = None
    return rt


def _mixed_trace(rids, lens, gens):
    return [_req(r, L=L, gen=g) for r, (L, g) in
            zip(rids, zip(lens, gens))]


class TestEngineTokens:
    """Continuous batching must be a pure scheduling change: every
    request's tokens equal its standalone greedy decode."""

    def test_dense_padded_buckets_exact(self, dense_rt):
        # lengths straddle two pow2 buckets (8 and 16); interleaved joins
        reqs = _mixed_trace(range(6), (5, 8, 11, 16, 7, 9),
                            (4, 6, 2, 5, 3, 6))
        eng = Engine(dense_rt, capacity=3)
        eng.run(reqs, respect_arrivals=False)
        assert len(eng.completed) == 6
        for r in eng.completed:
            np.testing.assert_array_equal(
                r.tokens, _reference_decode(dense_rt, r),
                err_msg=f"rid={r.rid} L={r.prompt_len}")

    def test_rwkv_exact_buckets_exact(self, rwkv_rt):
        reqs = _mixed_trace(range(5), (6, 9, 12, 6, 9), (4, 5, 2, 6, 3))
        eng = Engine(rwkv_rt, capacity=2)
        eng.run(reqs, respect_arrivals=False)
        for r in eng.completed:
            np.testing.assert_array_equal(
                r.tokens, _reference_decode(rwkv_rt, r),
                err_msg=f"rid={r.rid} L={r.prompt_len}")

    def test_gen1_finishes_without_decoding(self, rwkv_rt):
        reqs = [_req(0, L=8, gen=1), _req(1, L=8, gen=3)]
        eng = Engine(rwkv_rt, capacity=2)
        rep = eng.run(reqs, respect_arrivals=False)
        assert rep["n_requests"] == 2
        r0 = next(r for r in eng.completed if r.rid == 0)
        np.testing.assert_array_equal(
            r0.tokens, _reference_decode(rwkv_rt, r0)[:1])


class TestEngineScheduling:
    def test_over_capacity_queues_not_ooms(self, rwkv_rt):
        reqs = [_req(i, L=8, gen=3) for i in range(7)]
        eng = Engine(rwkv_rt, capacity=2)
        rep = eng.run(reqs, respect_arrivals=False)
        assert rep["n_requests"] == 7 and rep["dropped"] == 0
        assert rep["pool"]["peak_in_use"] <= 2
        assert rep["queue"]["peak_depth"] >= 5   # the rest waited in queue
        assert rep["pool"]["reused_slots"] >= 5  # slot indices recycled

    def test_static_mode_takes_more_steps(self, rwkv_rt):
        # one long request per pair: static drains to the long tail
        reqs = [_req(i, L=8, gen=(12 if i % 2 else 2)) for i in range(6)]
        cont = Engine(rwkv_rt, capacity=2, join_policy="continuous")
        c = cont.run([_req(r.rid, L=r.prompt_len, gen=r.max_new_tokens)
                      for r in reqs], respect_arrivals=False)
        stat = Engine(rwkv_rt, capacity=2, join_policy="static")
        s = stat.run(reqs, respect_arrivals=False)
        assert s["n_requests"] == c["n_requests"] == 6
        assert s["steps"] > c["steps"]
        assert c["occupancy"] > s["occupancy"]

    def test_token_budget_respected(self, rwkv_rt):
        reqs = [_req(i, L=8, gen=4) for i in range(4)]      # 12 tokens each
        eng = Engine(rwkv_rt, capacity=4, max_batch_tokens=25)  # fits 2
        rep = eng.run(reqs, respect_arrivals=False)
        assert rep["n_requests"] == 4
        assert rep["pool"]["peak_in_use"] <= 2

    def test_oversized_request_rejected(self, rwkv_rt):
        eng = Engine(rwkv_rt, capacity=2)
        with pytest.raises(ValueError, match="max_seq"):
            eng.run([_req(0, L=MAX_SEQ, gen=8)])

    def test_p99_and_throughput_reported(self, rwkv_rt):
        eng = Engine(rwkv_rt, capacity=2)
        rep = eng.run([_req(i, gen=2) for i in range(3)],
                      respect_arrivals=False)
        assert math.isfinite(rep["latency_p99_s"])
        assert rep["requests_per_s"] > 0 and rep["tokens_per_s"] > 0
        assert rep["fetch_batches"] >= 1   # delegatestore: batched fetches

    def test_respects_arrival_times(self, rwkv_rt):
        reqs = [_req(0, gen=2, arrival=0.0), _req(1, gen=2, arrival=0.05)]
        eng = Engine(rwkv_rt, capacity=2)
        eng.run(reqs, respect_arrivals=True)
        r1 = next(r for r in eng.completed if r.rid == 1)
        assert r1.t_admit >= 0.05          # not admitted before it arrived


# ---------------------------------------------------------------------------
# Donation (satellite a + c)
# ---------------------------------------------------------------------------

def _donation_supported():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    x = jnp.ones((4,), jnp.float32)
    f(x)
    return x.is_deleted()


class TestDonationDefault:
    def test_jax_backend_donates_by_default(self):
        from repro.core.backend import (JaxDeviceBackend, PinnedHostBackend,
                                        get_backend)
        assert JaxDeviceBackend().donate
        assert PinnedHostBackend().donate
        assert get_backend("jax").donate
        assert not JaxDeviceBackend(donate=False).donate  # explicit opt-out

    def test_pool_insert_donates_buffers(self, rwkv_rt):
        """Slot recycling reuses device memory: the donated insert must
        consume the previous pooled buffers."""
        if not _donation_supported():
            pytest.skip("platform does not implement buffer donation")
        import jax
        pool = KVSlotPool(rwkv_rt.model, capacity=2, max_seq=MAX_SEQ)
        slot = pool.alloc()
        before = jax.tree.leaves(pool.cache)
        _, cache = rwkv_rt.prefill_request(_req(0, L=8, gen=2))
        pool.insert(cache, 0, slot)
        assert all(leaf.is_deleted() for leaf in before)
        pool.free(slot)
        pool.assert_no_leaks()


# ---------------------------------------------------------------------------
# Shape buckets ↔ persistent tune cache (tentpole acceptance)
# ---------------------------------------------------------------------------

class TestBucketTuneCache:
    def test_warm_runtime_measures_nothing(self, rwkv_cfg):
        """A fresh runtime in the same (isolated) cache dir must find every
        bucket already measured: repeated traffic is pure cache hits."""
        reqs = lambda: [_req(i, L=8, gen=2) for i in range(3)]  # noqa: E731
        rt1 = ServeRuntime(rwkv_cfg, max_seq=16, seed=0)
        assert rt1.tune is not None     # conftest points REPRO_TUNE_CACHE
        Engine(rt1, capacity=2).run(reqs(), respect_arrivals=False)
        assert rt1.tune_measurements == 1          # one bucket, one measure
        assert rt1._buckets == {8: "measured"}

        rt2 = ServeRuntime(rwkv_cfg, max_seq=16, seed=0)
        Engine(rt2, capacity=2).run(reqs(), respect_arrivals=False)
        assert rt2.tune_measurements == 0          # warm: zero measurements
        assert rt2.tune_hits >= 3
        assert rt2._buckets == {8: "cached"}

    def test_fingerprint_varies_with_bucket(self, rwkv_cfg):
        rt = ServeRuntime(rwkv_cfg, max_seq=16, seed=0)
        assert (rt._bucket_fingerprint(8) != rt._bucket_fingerprint(16))


# ---------------------------------------------------------------------------
# launch.serve (satellite b) + load generator
# ---------------------------------------------------------------------------

class TestServeOneShot:
    def test_gen1_reports_sane_metrics(self, rwkv_cfg):
        from repro.launch.serve import serve
        out = serve(rwkv_cfg, batch=2, prompt_len=4, gen=1)
        assert out["generated"].shape == (2, 1)
        assert out["decode_tok_s"] == 0.0          # no decode loop ran
        assert math.isfinite(out["tokens_per_s"])
        # end-to-end rate is bounded by actual elapsed time
        total = out["prefill_s"] + out["decode_s"]
        assert out["tokens_per_s"] == pytest.approx(2 / total, rel=1e-6)

    def test_gen2_decode_rate_positive(self, rwkv_cfg):
        from repro.launch.serve import serve
        out = serve(rwkv_cfg, batch=2, prompt_len=4, gen=2)
        assert out["generated"].shape == (2, 2)
        assert out["decode_tok_s"] > 0.0


class TestLoadGenerator:
    def test_seeded_and_sorted(self, rwkv_cfg):
        a = make_trace(rwkv_cfg, n_requests=10, rate_rps=100.0, seed=7)
        b = make_trace(rwkv_cfg, n_requests=10, rate_rps=100.0, seed=7)
        for x, y in zip(a, b):
            assert x.arrival_s == y.arrival_s
            np.testing.assert_array_equal(x.prompt, y.prompt)
        assert all(a[i].arrival_s <= a[i + 1].arrival_s
                   for i in range(len(a) - 1))

    def test_max_seq_clamp(self, rwkv_cfg):
        t = make_trace(rwkv_cfg, n_requests=40, rate_rps=1e6, seed=0,
                       max_seq=16)
        assert all(r.total_tokens <= 16 for r in t)

    def test_embeds_arch_prompts(self):
        cfg = reduced(get_config("chameleon-34b"))
        if not cfg.input_embeds:
            pytest.skip("arch does not use input embeds")
        t = make_trace(cfg, n_requests=3, rate_rps=1e6, seed=0)
        assert all(r.prompt.ndim == 2 and r.prompt.shape[1] == cfg.d_model
                   for r in t)


class TestServeBenchSmoke:
    def test_quick_bench_invariants(self, tmp_path):
        """The CI smoke: tiny trace, both modes finish everything, p99
        finite, zero leaks, warm run measures nothing (no speedup gate —
        scheduling wins need a bigger trace than a unit test should pay
        for)."""
        import sys
        sys.path.insert(0, "benchmarks")
        try:
            import serve_bench
        finally:
            sys.path.pop(0)
        row = serve_bench.bench(arch="rwkv6-3b", n_requests=8, capacity=2,
                                max_seq=32, seed=0, gate=False)
        assert row["warm_tune_measurements"] == 0
        assert row["pool"]["in_use"] == 0
        assert math.isfinite(row["continuous"]["latency_p99_s"])
        assert math.isfinite(row["static"]["latency_p99_s"])
