"""Data pipeline: determinism, learnable structure, prefetch state."""
import numpy as np

from repro.configs import get_config, reduced
from repro.data import PrefetchIterator, SyntheticLM


def test_batches_deterministic():
    cfg = reduced(get_config("internlm2-20b"))
    src = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    a = src.batch_at(7)
    b = src.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_next_tokens():
    cfg = reduced(get_config("internlm2-20b"))
    src = SyntheticLM(cfg, batch=2, seq=32, seed=0)
    b = src.batch_at(0)
    # labels[t] == tokens[t+1] wherever no reset happened
    match = (b["labels"][:, :-1] == b["tokens"][:, 1:]).mean()
    assert match == 1.0


def test_prefetch_iterator_order_and_state():
    cfg = reduced(get_config("internlm2-20b"))
    src = SyntheticLM(cfg, batch=2, seq=8, seed=1)
    it = PrefetchIterator(src, start_index=0)
    b0 = next(it)
    b1 = next(it)
    state = it.state_dict()
    it.close()
    assert state["index"] == 2
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  src.batch_at(0)["tokens"])
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  src.batch_at(1)["tokens"])
    # resume exactly where we stopped
    it2 = PrefetchIterator.restore(src, state)
    b2 = next(it2)
    it2.close()
    np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                  src.batch_at(2)["tokens"])


def test_musicgen_embeds_batch():
    cfg = reduced(get_config("musicgen-large"))
    src = SyntheticLM(cfg, batch=2, seq=8, seed=1)
    b = src.batch_at(0)
    assert b["embeds"].shape == (2, 8, cfg.d_model)
    assert b["labels"].shape == (2, 8, cfg.n_codebooks)
