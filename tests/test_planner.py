"""Unit tests for the paper's directive-placement optimization."""
import numpy as np

from repro.core import (AdvancedLoad, Callsite, Program, analyze, emit,
                        execute, naive_plan, plan, run_host_oracle,
                        transfer_summary)
from repro.core.ir import VarIO


def fig1_program():
    """Paper Fig. 1: host writes A; kernel C = A*k; host reads C."""
    p = Program("fig1")
    p.bind("A", np.arange(16, dtype=np.float32))
    p.bind("k", np.float32(3.0))
    p.host(lambda xp, A: {"A": A + 1.0}, reads=("A",), writes=("A",),
           name="writeA")
    p.offload(lambda xp, A, k: {"C": A * k}, reads=("A", "k"),
              writes=("C",), name="kernel")
    p.host(lambda xp, C: {"res": C * 2.0}, reads=("C",), writes=("res",),
           name="readC")
    p.set_outputs("res")
    return p


class TestIOClassification:
    def test_fig1_io(self):
        p = fig1_program()
        an = analyze(p)
        io = an.io_table[p.blocks[1].idx]
        assert io["A"] is VarIO.IN
        assert io["k"] is VarIO.IN
        assert io["C"] is VarIO.OUT

    def test_out_var_not_uploaded(self):
        """Paper: E is written before read inside the kernel → io=out →
        no advancedload for E."""
        p = Program()
        p.bind("A", np.ones((4, 4), np.float32))
        p.offload(lambda xp, A: {"E": A @ A}, reads=("A",), writes=("E",),
                  name="k")
        p.host(lambda xp, E: {"o": E + 1}, reads=("E",), writes=("o",),
               name="c")
        p.set_outputs("o")
        pl = plan(p)
        loads = [d.var for d in pl.directives(AdvancedLoad)]
        assert "E" not in loads
        assert "A" in loads

    def test_inout_classification(self):
        p = Program()
        p.bind("C", np.ones((4,), np.float32))
        p.offload(lambda xp, C: {"C": C + 1}, reads=("C",), writes=("C",),
                  name="acc")
        p.set_outputs("C")
        an = analyze(p)
        assert an.io_table[0]["C"] is VarIO.INOUT

    def test_unused_declared_read_pruned(self):
        """jaxpr-level pruning: a declared-but-unread input needs no load —
        the analogue of the paper's AST analysis of actual uses."""
        p = Program()
        p.bind("A", np.ones((4,), np.float32))
        p.bind("B", np.ones((4,), np.float32))
        p.offload(lambda xp, A, B: {"C": A * 2.0}, reads=("A", "B"),
                  writes=("C",), name="k")
        p.host(lambda xp, C: {"o": C}, reads=("C",), writes=("o",),
               name="c")
        p.set_outputs("o")
        pl = plan(p)
        loads = [d.var for d in pl.directives(AdvancedLoad)]
        assert loads == ["A"]


class TestPlacement:
    def test_fig2_load_hoisted_out_of_writer_loop(self):
        """Host writes A inside a loop; kernel after → single load placed
        after the loop (Fig. 2), executed once."""
        p = Program()
        p.bind("A", np.ones((8, 8), np.float32))
        with p.loop(5):
            p.host(lambda xp, A: {"A": A * 1.1}, reads=("A",),
                   writes=("A",), name="w")
        p.offload(lambda xp, A: {"C": A @ A}, reads=("A",), writes=("C",),
                  name="k")
        p.host(lambda xp, C: {"o": C + 1}, reads=("C",), writes=("o",),
               name="c")
        p.set_outputs("o")
        _, stats = execute(plan(p))
        assert stats.h2d_transfers == 1
        d = plan(p).directives(AdvancedLoad)
        a_load = [x for x in d if x.var == "A"][0]
        assert a_load.hoisted_from, "load should record hoisted loops"

    def test_fig3_store_before_reader_loop(self):
        """Kernel before a nested host loop reading B → one store placed
        before the loops (Fig. 3), not one per iteration."""
        p = Program()
        p.bind("A", np.ones((8, 8), np.float32))
        p.bind("acc", np.zeros((1,), np.float32))
        p.offload(lambda xp, A: {"B": A * 2}, reads=("A",), writes=("B",),
                  name="k")
        with p.loop(4):
            with p.loop(3):
                p.host(lambda xp, B, acc: {"acc": acc + B.sum(
                    keepdims=True)[:1]}, reads=("B", "acc"),
                    writes=("acc",), name="r")
        p.set_outputs("acc")
        _, stats = execute(plan(p))
        assert stats.d2h_transfers == 1
        _, nstats = execute(naive_plan(p))
        assert nstats.d2h_transfers == 1  # naive stores at callsite: also 1

    def test_loop_kernel_residency(self):
        """Kernel inside a loop, inputs written before it: naive uploads
        every iteration, optimized uploads once (noupdate)."""
        p = Program()
        p.bind("A", np.ones((16, 16), np.float32))
        p.bind("C", np.ones((16, 16), np.float32))
        with p.loop(6):
            p.offload(lambda xp, A, C: {"C": 0.5 * (A @ C)},
                      reads=("A", "C"), writes=("C",), name="k")
        p.host(lambda xp, C: {"o": C.sum(keepdims=True)[:1]},
               reads=("C",), writes=("o",), name="c")
        p.set_outputs("o")
        _, s_opt = execute(plan(p))
        _, s_nv = execute(naive_plan(p))
        assert s_opt.h2d_transfers == 2          # A and C, once each
        assert s_nv.h2d_transfers == 12          # 2 per iteration
        assert s_opt.d2h_transfers == 1
        assert s_nv.d2h_transfers == 6

    def test_host_write_in_loop_invalidates(self):
        """Host write inside the kernel's loop → residency is NOT assumed
        (reload each iteration), results still exact."""
        p = Program()
        p.bind("A", np.ones((8,), np.float32))
        with p.loop(4):
            p.host(lambda xp, A: {"A": A + 1.0}, reads=("A",),
                   writes=("A",), name="w")
            p.offload(lambda xp, A: {"B": A * 2.0}, reads=("A",),
                      writes=("B",), name="k")
        p.host(lambda xp, B: {"o": B}, reads=("B",), writes=("o",),
               name="c")
        p.set_outputs("o")
        out, stats = execute(plan(p))
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out["o"], oracle["o"], rtol=1e-6)
        assert stats.h2d_transfers == 4          # once per iteration


class Test3MM:
    def test_noupdate_and_grouping(self):
        from repro.polybench import build_3mm
        p, _ = build_3mm(n=32)
        pl = plan(p)
        calls = {c.block_idx: c for c in pl.directives(Callsite)}
        # kernel mm_G consumes device-resident E and F
        g_idx = [b.idx for b in p.offload_blocks() if b.name == "mm_G"][0]
        assert set(calls[g_idx].noupdate) == {"E", "F"}
        # one group holds all three kernels (shared E, F)
        assert len(pl.groups) == 1
        s = transfer_summary(pl)
        assert s["loads"] == 4 and s["stores"] == 1

    def test_naive_vs_optimized_counts(self):
        from repro.polybench import build_3mm
        p, _ = build_3mm(n=32)
        _, s_opt = execute(plan(p))
        _, s_nv = execute(naive_plan(p))
        assert s_opt.h2d_transfers == 4 and s_nv.h2d_transfers == 6
        assert s_opt.d2h_transfers == 1 and s_nv.d2h_transfers == 3

    def test_emitter_matches_table2_structure(self):
        from repro.polybench import build_3mm
        p, _ = build_3mm(n=32)
        text = emit(plan(p))
        assert "group, target=TPU" in text
        assert "mapbyname, E, F" in text
        assert "noupdate=true" in text
        assert text.count("advancedload") == 4
        assert text.count("delegatedstore") == 1
        assert "synchronize" in text
        assert "release" in text


class TestSyncPlacement:
    def test_sync_before_first_host_use(self):
        p = fig1_program()
        pl = plan(p)
        kinds = []
        for op in pl.ops:
            if op.kind == "directive":
                kinds.append(type(op.directive).__name__)
            elif op.kind == "block":
                kinds.append(f"block:{pl.program.blocks[op.block_idx].name}")
        i_sync = kinds.index("Synchronize")
        i_store = kinds.index("DelegateStore")
        i_read = kinds.index("block:readC")
        assert i_sync < i_store < i_read
