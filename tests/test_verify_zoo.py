"""Every program in the repo verifies clean under every placement policy.

ISSUE 7 satellite: the verifier must accept all plans the planner can
produce — the polybench suite, the optimizer-offload train-step builders
and the kernel-tagged attention step, across every registered placement.
Naive plans are allowed (expected, for 3MM) to carry redundant-transfer
*lints*; none may carry errors.
"""
import pytest

from repro.core import placement_names, plan, verify_plan
from repro.optim import attention_step_program, plan_step_program
from repro.polybench import PROBLEMS, build

POLICIES = placement_names()


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("name", sorted(PROBLEMS))
def test_polybench_verifies_clean(name, policy):
    p = build(name, n=32)[0]
    pl = plan(p, policy=policy)
    rep = verify_plan(pl)
    assert rep.ok, rep.summary()
    assert pl.meta["verify"]["ok"] is True


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("builder", [plan_step_program,
                                     attention_step_program],
                         ids=["train_step", "attention_step"])
def test_offload_builders_verify_clean(builder, policy):
    pl = plan(builder(n_steps=1), policy=policy)
    assert verify_plan(pl).ok


def test_naive_3mm_lints_but_verifies(polybench_3mm=None):
    """The paper's running example: naive placement wastes transfers on
    E and F — lints, not errors (Table 2 motivation)."""
    pl = plan(build("3mm", n=32)[0], policy="naive")
    rep = verify_plan(pl)
    assert rep.ok and rep.counts().get("redundant-directive", 0) >= 2
