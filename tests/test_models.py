"""Per-arch smoke tests (reduced configs) + cache-consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.models import Transformer

RNG = np.random.default_rng(7)


def _batch(cfg, B=2, S=32):
    out = {}
    if cfg.input_embeds:
        out["embeds"] = jnp.asarray(RNG.standard_normal(
            (B, S, cfg.d_model)).astype(np.float32))
    else:
        out["tokens"] = jnp.asarray(
            RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    lshape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    out["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab, lshape),
                                jnp.int32)
    return out


@pytest.mark.parametrize("full_cfg", ALL_ARCHS, ids=lambda c: c.name)
def test_arch_smoke_forward(full_cfg):
    """Reduced same-family config: one forward pass, finite loss, correct
    output shapes (the FULL config is exercised by the dry-run)."""
    cfg = reduced(full_cfg)
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss)), full_cfg.name
    assert float(loss) > 0


@pytest.mark.parametrize("name", ["internlm2-20b", "recurrentgemma-2b",
                                  "rwkv6-3b"])
def test_arch_train_step(name):
    cfg = reduced(get_config(name))
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)
    grads, _ = jax.grad(m.loss, has_aux=True)(params, batch)
    sq = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(sq) and sq > 0


@pytest.mark.parametrize("name", ["internlm2-20b", "qwen2.5-14b",
                                  "recurrentgemma-2b", "rwkv6-3b",
                                  "musicgen-large", "chameleon-34b"])
def test_decode_matches_prefill(name):
    """decode_step after prefill(S) == last logits of prefill(S+1)."""
    cfg = reduced(get_config(name))
    m = Transformer(cfg)
    params = m.init(jax.random.key(1))
    B, S = 2, 24
    toks = RNG.integers(0, cfg.vocab, (B, S + 1))
    if cfg.input_embeds:
        emb = RNG.standard_normal((B, S + 1, cfg.d_model)).astype(
            np.float32)
        b_s = {"embeds": jnp.asarray(emb[:, :S])}
        b_s1 = {"embeds": jnp.asarray(emb)}
        nxt = {"embeds": jnp.asarray(emb[:, S])}
    else:
        b_s = {"tokens": jnp.asarray(toks[:, :S], jnp.int32)}
        b_s1 = {"tokens": jnp.asarray(toks, jnp.int32)}
        nxt = {"tokens": jnp.asarray(toks[:, S], jnp.int32)}
    _, cache = m.prefill(params, b_s, max_seq=S + 8)
    ld, _ = m.decode_step(params, cache, nxt, jnp.full((B,), S, jnp.int32))
    lf, _ = m.prefill(params, b_s1, max_seq=S + 9)
    a = np.asarray(ld, np.float32)
    b = np.asarray(lf, np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3,
                               atol=2e-3 * np.abs(b).max())


def test_moe_decode_matches_prefill_no_dropping():
    """MoE consistency holds exactly when capacity never drops (the
    residual mismatch under dropping is the documented GShard behavior)."""
    cfg = dataclasses.replace(reduced(get_config("qwen3-moe-30b-a3b")),
                              capacity_factor=1000.0)
    m = Transformer(cfg)
    params = m.init(jax.random.key(1))
    B, S = 2, 16
    toks = RNG.integers(0, cfg.vocab, (B, S + 1))
    b_s = {"tokens": jnp.asarray(toks[:, :S], jnp.int32)}
    b_s1 = {"tokens": jnp.asarray(toks, jnp.int32)}
    nxt = {"tokens": jnp.asarray(toks[:, S], jnp.int32)}
    _, cache = m.prefill(params, b_s, max_seq=S + 4)
    ld, _ = m.decode_step(params, cache, nxt, jnp.full((B,), S, jnp.int32))
    lf, _ = m.prefill(params, b_s1, max_seq=S + 5)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf), rtol=2e-3,
                               atol=2e-3 * np.abs(np.asarray(lf)).max())


def test_moe_vs_dense_oracle():
    """Capacity-∞ MoE == explicit per-token expert loop."""
    from repro.models.moe import moe_apply
    from repro.models.layers import init_tree
    from repro.models.moe import moe_spec

    cfg = dataclasses.replace(reduced(get_config("qwen3-moe-30b-a3b")),
                              capacity_factor=1000.0)
    spec = moe_spec(cfg)
    params = init_tree(spec, jax.random.key(3), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model))
                    .astype(np.float32))
    out, aux = moe_apply(params, x, cfg)

    # oracle: per-token dense loop
    logits = x.reshape(-1, cfg.d_model) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    g, idx = jax.lax.top_k(probs, cfg.top_k)
    g = g / g.sum(-1, keepdims=True)
    xf = x.reshape(-1, cfg.d_model)
    want = np.zeros_like(np.asarray(xf))
    ew = params["experts"]
    for t in range(xf.shape[0]):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(xf[t] @ ew["w_gate"][e]) * (xf[t] @ ew["w_up"][e])
            want[t] += float(g[t, j]) * np.asarray(h @ ew["w_down"][e])
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               want, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_musicgen_multihead_shapes():
    cfg = reduced(get_config("musicgen-large"))
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, cache = m.prefill(params, batch, max_seq=S + 4)
    assert logits.shape == (B, cfg.n_codebooks, cfg.vocab)


def test_rwkv_long_context_state_is_constant_memory():
    """Attention-free arch: cache size is independent of sequence length —
    the reason long_500k runs for rwkv6/recurrentgemma only."""
    cfg = reduced(get_config("rwkv6-3b"))
    m = Transformer(cfg)
    c1 = jax.eval_shape(lambda: m.init_cache(1, 1_000))
    c2 = jax.eval_shape(lambda: m.init_cache(1, 500_000))
    def sz(t):
        return sum(np.prod(x.shape) for x in jax.tree.leaves(t))
    assert sz(c1) == sz(c2)


def test_kv_quant_decode_close_to_fp():
    """int8 KV cache (§Perf 'kvq8'): greedy-decode logits stay close to the
    bf16 cache over multiple steps."""
    cfg = reduced(get_config("internlm2-20b"))
    m = Transformer(cfg)
    mq = Transformer(cfg, kv_quant=True)
    params = m.init(jax.random.key(0))
    B = 2
    toks = RNG.integers(0, cfg.vocab, (B, 8))
    cache, cacheq = m.init_cache(B, 16), mq.init_cache(B, 16)
    assert cacheq["k"].dtype == jnp.int8
    err = 0.0
    for t in range(8):
        tok = {"tokens": jnp.asarray(toks[:, t], jnp.int32)}
        pos = jnp.full((B,), t, jnp.int32)
        l1, cache = m.decode_step(params, cache, tok, pos)
        l2, cacheq = mq.decode_step(params, cacheq, tok, pos)
        err = max(err, float(np.max(np.abs(
            np.asarray(l1, np.float32) - np.asarray(l2, np.float32)))))
    assert err < 0.25, err
    # k/v bytes shrink by the dtype itemsize (bf16→int8: 2×; fp32→int8: 4×)
    def sz(c):
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for k, x in c.items() if k in ("k", "v"))
    ratio = np.dtype(cfg.dtype).itemsize
    assert sz(cacheq) * ratio == sz(cache)
