"""Fault-tolerance machinery: heartbeat, watchdog, elastic decisions,
retry supervision."""
import pytest

from repro.runtime import (ElasticController, FaultInjector, Heartbeat,
                           StepWatchdog, run_with_retries)


def test_heartbeat_dead_host_detection():
    hb = Heartbeat(timeout=5.0)
    hb.tick("h0", now=100.0)
    hb.tick("h1", now=100.0)
    hb.tick("h0", now=109.0)
    assert hb.dead_hosts(now=110.0) == ["h1"]
    assert hb.live_hosts(now=110.0) == ["h0"]


def test_watchdog_flags_straggler():
    wd = StepWatchdog(factor=2.0)
    for h in ("h0", "h1", "h2", "h3"):
        wd.record(h, 1.0)
    wd.record("h2", 5.0)
    assert wd.stragglers() == ["h2"]


def test_watchdog_no_false_positive():
    wd = StepWatchdog(factor=2.0)
    for h in ("h0", "h1"):
        wd.record(h, 1.0)
    assert wd.stragglers() == []


def test_elastic_controller_shrinks_data_axis():
    ec = ElasticController(chips_per_host=4, model_axis=16)
    d = ec.decide(n_live_hosts=128)         # 512 chips
    assert d.mesh_shape == (32, 16)
    d = ec.decide(n_live_hosts=100)         # 400 chips -> data 16 (pow2)
    assert d.mesh_shape == (16, 16)
    with pytest.raises(RuntimeError):
        ec.decide(n_live_hosts=2)


def test_run_with_retries():
    inj = FaultInjector((0, 1))
    calls = []

    def train_fn(_):
        step = len(calls)
        calls.append(step)
        inj.maybe_fail(step)
        return 99

    final, restarts = run_with_retries(train_fn, max_restarts=3)
    assert final == 99 and restarts == 2


def test_run_with_retries_exhausted():
    def always_fail(_):
        raise RuntimeError("boom")
    with pytest.raises(RuntimeError):
        run_with_retries(always_fail, max_restarts=2)
