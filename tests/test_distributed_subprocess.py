"""Multi-device tests that need XLA_FLAGS device-count forcing — each runs
in a subprocess so the main pytest process keeps its single CPU device."""
import subprocess
import sys
import textwrap
from pathlib import Path


SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_py(body: str, timeout=560):
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(body))
    # JAX_PLATFORMS=cpu: without it jax probes for a TPU backend first
    # (minutes of metadata-server retries on a non-TPU host)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_small_mesh_dryrun_train_and_decode():
    out = run_py("""
        import jax
        from repro.configs import get_config, reduced, ShapeSpec
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        mesh = make_mesh((2, 4), ("data", "model"))
        for arch in ["internlm2-20b", "qwen3-moe-30b-a3b",
                     "recurrentgemma-2b", "rwkv6-3b"]:
            cfg = reduced(get_config(arch))
            for sh in [ShapeSpec("t", "train", 64, 8),
                       ShapeSpec("d", "decode", 64, 8)]:
                cell = build_cell(cfg, sh, mesh)
                with mesh:
                    c = cell.lower().compile()
                assert c.memory_analysis().temp_size_in_bytes > 0
        print("DRYRUN_SMALL_OK")
    """)
    assert "DRYRUN_SMALL_OK" in out


def test_multipod_mesh_small():
    out = run_py("""
        import jax
        from repro.configs import get_config, reduced, ShapeSpec
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced(get_config("internlm2-20b"))
        cell = build_cell(cfg, ShapeSpec("t", "train", 64, 8), mesh)
        with mesh:
            c = cell.lower().compile()
        txt = c.as_text()
        assert "all-" in txt or "collective" in txt
        print("MULTIPOD_OK")
    """)
    assert "MULTIPOD_OK" in out


def test_sharded_train_step_matches_single_device():
    """The distributed train step computes the same loss as the
    un-sharded one (GSPMD correctness check)."""
    out = run_py("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, reduced, ShapeSpec
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell, input_specs
        from repro.models import Transformer
        from repro.optim import default_optimizer
        cfg = reduced(get_config("internlm2-20b"))
        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeSpec("t", "train", 32, 8)
        cell = build_cell(cfg, shape, mesh)
        with mesh:
            fn = cell.jitted()
        model = Transformer(cfg)
        params = model.init(jax.random.key(0))
        opt = default_optimizer(cfg)
        opt_state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {
          "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                jnp.int32),
          "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                jnp.int32),
        }
        with mesh:
            _, _, metrics = fn(params, opt_state, batch)
        sharded_loss = float(metrics["loss"])
        ref_loss = float(model.loss(params, batch)[0])
        assert abs(sharded_loss - ref_loss) < 5e-3, (sharded_loss, ref_loss)
        print("SHARDED_MATCH_OK", sharded_loss, ref_loss)
    """)
    assert "SHARDED_MATCH_OK" in out


def test_pipeline_forward_oracle():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline import pipeline_forward
        mesh = make_mesh((4, 2), ("pod", "data"))
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.standard_normal((4, 16, 16)).astype(np.float32)
                        * 0.3)
        x = jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32))
        layer = lambda w, mb: jnp.tanh(mb @ w)
        run = pipeline_forward(mesh, layer, n_microbatches=4)
        with mesh:
            y = run(W, x)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ W[i])
        assert float(jnp.max(jnp.abs(y - ref))) < 1e-5
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_compressed_psum_and_error_feedback():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.distributed.collectives import (psum_compressed,
                                                   ErrorFeedback)
        mesh = make_mesh((4, 2), ("pod", "data"))
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))
        with mesh:
            out = shard_map(lambda x: psum_compressed(x, "pod"), mesh=mesh,
                            in_specs=(P("pod"),), out_specs=P("pod"),
                            check_rep=False)(g)
        ref = jnp.broadcast_to(g.sum(axis=0), (4, 256))
        rel = float(jnp.max(jnp.abs(out - ref)) /
                    (jnp.max(jnp.abs(ref)) + 1e-9))
        assert rel < 0.02, rel

        # error feedback: compressed-SGD converges like exact on quadratic
        def compress(x):
            from repro.distributed.collectives import (quantize_int8,
                                                       dequantize_int8)
            q, s = quantize_int8(x)
            return dequantize_int8(q, s)
        w = jnp.ones((64,)) * 5.0
        w_exact = jnp.ones((64,)) * 5.0
        err = ErrorFeedback.init({"w": w})
        for _ in range(200):
            comp, err = ErrorFeedback.apply({"w": 2 * w}, err, compress)
            w = w - 0.01 * comp["w"]
            w_exact = w_exact - 0.01 * (2 * w_exact)
        # compressed + error feedback tracks the exact trajectory
        gap = float(jnp.max(jnp.abs(w - w_exact)))
        assert gap < 5e-3, gap
        print("COLLECTIVES_OK")
    """)
    assert "COLLECTIVES_OK" in out


def test_elastic_remesh_checkpoint_restore():
    """Save under an 8-device sharded layout, restore under a DIFFERENT
    mesh shape — the elastic-rescale path."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.checkpoint import CheckpointManager
        tmp = tempfile.mkdtemp()
        mesh_a = make_mesh((4, 2), ("data", "model"))
        tree = {"w": jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh_a, P("data", "model")))}
        mgr = CheckpointManager(tmp)
        mgr.save(1, tree, blocking=True)
        mesh_b = make_mesh((2, 4), ("data", "model"))
        sh_b = {"w": NamedSharding(mesh_b, P("model", None))}
        restored, _ = mgr.restore(1, tree, sh_b)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.spec == P("model", None)
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_ep_moe_matches_gspmd_moe():
    """The expert-parallel shard_map MoE (§Perf, 19× collective win) must
    agree with the GSPMD einsum MoE when capacity never drops."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_mesh
        from repro.models import Transformer
        from repro.distributed import make_rules, MeshPolicy
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            reduced(get_config("qwen3-moe-30b-a3b")),
            capacity_factor=1000.0)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                  jnp.int32)}
        params = Transformer(cfg).init(jax.random.key(0))
        loss_ref = float(Transformer(cfg).loss(params, batch)[0])
        policy = MeshPolicy(make_rules(mesh, "train"), cfg)
        m_ep = Transformer(cfg, moe_ep=True)
        with mesh:
            loss_ep = float(jax.jit(
                lambda p, b: m_ep.loss(p, b, policy)[0])(params, batch))
            g = jax.jit(jax.grad(
                lambda p: m_ep.loss(p, batch, policy)[0]))(params)
        gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
        assert abs(loss_ref - loss_ep) < 5e-3, (loss_ref, loss_ep)
        assert np.isfinite(gn) and gn > 0
        print("EP_MOE_OK")
    """)
    assert "EP_MOE_OK" in out
