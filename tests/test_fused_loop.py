"""Whole-loop lowering (ISSUE 2 tentpole) + executor/planner bugfixes.

The fused-loop contract:

  * a loop whose body is pure device work (offload blocks only — no host
    blocks, no AdvancedLoad/DelegateStore/Release inside) executes as
    EXACTLY ONE backend dispatch in compiled mode (``lax.fori_loop`` on
    device backends, a Python loop inside one dispatch on numpy),
  * outputs stay bitwise-equal to interpreted mode on every backend,
  * logical ``ExecStats`` (kernel_calls, transfers, syncs) stay identical
    to interpreted mode — they count per iteration; ``fused_launches``
    counts 1.

Plus regression tests for the satellite bugfixes: group-scoped Release,
``compile_time`` accounting, one naive Synchronize per callsite, and the
host-oracle output contract.
"""
import numpy as np
import pytest

from repro.core import (AdvancedLoad, DelegateStore, JaxDeviceBackend, Program,
                        Release, Synchronize, execute, get_backend, naive_plan,
                        plan, run_host_oracle, transfer_summary)
from repro.core.ir import PlanOp
from repro.optim import plan_step_program
from repro.polybench import build


def _loop_prog(iters=6):
    """Kernel loop whose body is pure device: inputs hoisted before, the
    only download sunk after — the paper's residency case."""
    p = Program("fused")
    rng = np.random.default_rng(7)
    p.bind("A", rng.standard_normal((24, 24)).astype(np.float32))
    p.bind("C", rng.standard_normal((24, 24)).astype(np.float32))
    with p.loop(iters):
        p.offload(lambda xp, A, C: {"C": 0.25 * (A @ C) + C},
                  reads=("A", "C"), writes=("C",), name="k")
    p.host(lambda xp, C: {"out": C.sum(axis=0, keepdims=True)},
           reads=("C",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p


class TestFusedLoop:
    @pytest.mark.parametrize("backend", ["numpy", "jax", "pinned"])
    def test_bitwise_equal_and_logical_parity(self, backend):
        be = get_backend(backend)
        p = _loop_prog(iters=6)
        pl = plan(p)
        out_i, s_i = execute(pl, mode="interpreted", backend=be)
        out_c, s_c = execute(pl, mode="compiled", backend=be)
        np.testing.assert_array_equal(out_i["out"], out_c["out"])
        assert s_i.transfer_counts() == s_c.transfer_counts()
        assert s_c.kernel_calls == 6          # logical: one per iteration
        assert s_c.fused_launches == 1        # physical: one for the loop

    @pytest.mark.parametrize("backend", ["numpy", "jax", "pinned"])
    def test_single_backend_dispatch(self, backend):
        """The backend's own dispatch counter: an eligible N-iteration
        loop is exactly 1 launch_loop call."""
        be = get_backend(backend)
        p = _loop_prog(iters=5)
        before = be.loop_dispatches
        _, s_c = execute(plan(p), mode="compiled", backend=be)
        assert be.loop_dispatches - before == 1
        assert s_c.fused_launches == 1

    def test_planner_marks_pure_device_loops(self):
        pl = plan(_loop_prog())
        assert len(pl.pure_device_loops()) == 1
        # a load inside the loop body (naive policy) disqualifies it
        nv = naive_plan(_loop_prog())
        assert nv.pure_device_loops() == ()

    def test_host_block_in_loop_not_fused(self):
        p = Program()
        p.bind("A", np.ones((8,), np.float32))
        with p.loop(4):
            p.host(lambda xp, A: {"A": A + 1.0}, reads=("A",),
                   writes=("A",), name="w")
            p.offload(lambda xp, A: {"B": A * 2.0}, reads=("A",),
                      writes=("B",), name="k")
        p.host(lambda xp, B: {"o": B}, reads=("B",), writes=("o",),
               name="c")
        p.set_outputs("o")
        pl = plan(p)
        assert pl.pure_device_loops() == ()
        _, s_c = execute(pl, mode="compiled")
        assert s_c.fused_launches == 4        # one segment per iteration

    def test_multi_block_body_with_body_defined_state(self):
        """plan_step_program's loop body defines grad/loss inside the
        body (not device-resident at entry): the fused loop threads them
        through the carry and the post-loop download still sees the last
        iteration's value."""
        p = plan_step_program(n_steps=5)
        pl = plan(p)
        assert len(pl.pure_device_loops()) == 1
        out_i, s_i = execute(pl, mode="interpreted")
        out_c, s_c = execute(pl, mode="compiled")
        for k in p.outputs:
            np.testing.assert_array_equal(out_i[k], out_c[k])
        assert s_i.transfer_counts() == s_c.transfer_counts()
        assert s_c.kernel_calls == 10         # 2 blocks x 5 iterations
        assert s_c.fused_launches == 1

    def test_mutated_plan_body_load_disables_fusion(self):
        """Splicing an AdvancedLoad into a marked-pure loop body must not
        fuse (the structural check gates stale meta) and must keep count
        parity with the interpreter."""
        p = _loop_prog(iters=3)
        pl = plan(p)
        begin = next(i for i, op in enumerate(pl.ops)
                     if op.kind == "loop_begin")
        pl.ops.insert(begin + 1, PlanOp("directive", directive=AdvancedLoad(
            var="A", group=0, stream=1)))
        out_i, s_i = execute(pl, mode="interpreted")
        out_c, s_c = execute(pl, mode="compiled")
        np.testing.assert_array_equal(out_i["out"], out_c["out"])
        assert s_i.transfer_counts() == s_c.transfer_counts()
        assert s_c.h2d_transfers == s_i.h2d_transfers >= 3

    def test_emitter_prints_fused_region(self):
        from repro.core import emit
        text = emit(plan(_loop_prog()))
        assert "whole-loop lowering" in text
        assert "region" in text

    def test_compile_time_excluded_from_wall_time(self):
        p = _loop_prog(iters=3)
        pl = plan(p)
        _, s_first = execute(pl, mode="compiled")
        _, s_again = execute(pl, mode="compiled")
        assert s_first.compile_time > 0.0     # lowering happened once...
        assert s_again.compile_time == 0.0    # ...and was cached
        assert s_first.transfer_counts() == s_again.transfer_counts()


def _nested_prog(n_outer=3, n_inner=4, multi_block=False):
    """A pure-device nest: inputs hoisted before, the only download sunk
    after — both loops are planner-pure, so the whole nest may roll into
    ONE nested ``fori_loop`` dispatch."""
    p = Program("nest")
    rng = np.random.default_rng(11)
    p.bind("A", rng.standard_normal((16, 16)).astype(np.float32))
    p.bind("C", rng.standard_normal((16, 16)).astype(np.float32))
    with p.loop(n_outer):
        with p.loop(n_inner):
            p.offload(lambda xp, A, C: {"C": 0.25 * (A @ C) + C},
                      reads=("A", "C"), writes=("C",), name="k")
            if multi_block:
                p.offload(lambda xp, C: {"C": xp.tanh(C)},
                          reads=("C",), writes=("C",), name="squash")
    p.host(lambda xp, C: {"out": C.sum(axis=0, keepdims=True)},
           reads=("C",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p


class TestNestedFusedLoop:
    """ISSUE 4 satellite: an outer loop whose body lowers to exactly one
    _FusedLoop rolls into a nested ``lax.fori_loop``."""

    @pytest.mark.parametrize("backend", ["numpy", "jax", "pinned"])
    def test_nest_is_one_dispatch_bitwise_equal(self, backend):
        be = get_backend(backend)
        p = _nested_prog(n_outer=3, n_inner=4)
        pl = plan(p)
        # purity info from the pass pipeline covers the whole nest
        assert len(pl.pure_device_loops()) == 2
        before = be.loop_dispatches
        out_i, s_i = execute(pl, mode="interpreted", backend=be)
        out_c, s_c = execute(pl, mode="compiled", backend=be)
        np.testing.assert_array_equal(out_i["out"], out_c["out"])
        assert s_i.transfer_counts() == s_c.transfer_counts()
        assert s_c.kernel_calls == 12        # logical: 3 × 4 iterations
        assert s_c.fused_launches == 1       # physical: ONE for the nest
        assert be.loop_dispatches - before == 1

    def test_multi_block_inner_body(self):
        p = _nested_prog(n_outer=2, n_inner=3, multi_block=True)
        pl = plan(p)
        out_i, s_i = execute(pl, mode="interpreted")
        out_c, s_c = execute(pl, mode="compiled")
        np.testing.assert_array_equal(out_i["out"], out_c["out"])
        assert s_c.kernel_calls == 2 * 3 * 2
        assert s_c.fused_launches == 1

    def test_host_block_between_loops_blocks_outer_fusion(self):
        """Outer body = host block + inner loop → only the inner loop
        fuses; the outer loop re-enters per iteration."""
        p = Program("half_pure")
        p.bind("A", np.ones((8, 8), np.float32))
        p.bind("C", np.ones((8, 8), np.float32))
        p.bind("h", np.ones((2,), np.float32))
        with p.loop(3):
            p.host(lambda xp, h: {"h": h * 1.5}, reads=("h",),
                   writes=("h",), name="hostwork")
            with p.loop(4):
                p.offload(lambda xp, A, C: {"C": 0.5 * (A @ C)},
                          reads=("A", "C"), writes=("C",), name="k")
        p.host(lambda xp, C, h: {"out": C[:1] + h[:1]},
               reads=("C", "h"), writes=("out",), name="consume")
        p.set_outputs("out")
        pl = plan(p)
        assert len(pl.pure_device_loops()) == 1   # inner only
        out_i, s_i = execute(pl, mode="interpreted")
        out_c, s_c = execute(pl, mode="compiled")
        np.testing.assert_array_equal(out_i["out"], out_c["out"])
        assert s_i.transfer_counts() == s_c.transfer_counts()
        assert s_c.fused_launches == 3            # inner nest × 3 outer


def _donation_supported():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    x = jnp.ones((4,), jnp.float32)
    f(x)
    return x.is_deleted()


class TestFusedLoopDonation:
    """ISSUE 4 satellite: launch_loop donates rewritten entry vars like
    segment args do, behind the existing donate=True flag."""

    def test_donated_carry_buffer_reused(self):
        """The rewritten carry's pre-launch buffer is handed to the
        launch (marked deleted); read-only carry entries are kept."""
        if not _donation_supported():
            pytest.skip("platform does not implement buffer donation")
        be = JaxDeviceBackend(donate=True)
        A = be.upload(np.ones((8, 8), np.float32))
        C = be.upload(np.full((8, 8), 2.0, np.float32))
        ref = np.asarray(C)
        for _ in range(5):
            ref = 0.5 * (np.ones((8, 8), np.float32) @ ref)

        def body(env):
            return {"A": env["A"], "C": 0.5 * (env["A"] @ env["C"])}

        out = be.launch_loop(body, 5, {"A": A, "C": C},
                             donate_keys=("C",))
        np.testing.assert_allclose(np.asarray(out["C"]), ref, rtol=1e-5)
        assert C.is_deleted()           # buffer went to the launch
        assert not A.is_deleted()       # read-only state is kept

    def test_gated_behind_donate_flag(self):
        """donate=False (the explicit opt-out — donation is the default
        since ISSUE 8) must leave every carry buffer alive."""
        be = JaxDeviceBackend(donate=False)
        C = be.upload(np.ones((8, 8), np.float32))

        def body(env):
            return {"C": env["C"] * 2.0}

        be.launch_loop(body, 3, {"C": C}, donate_keys=("C",))
        assert not C.is_deleted()

    @pytest.mark.parametrize("nested", [False, True])
    def test_execute_parity_with_donation(self, nested):
        """Full pipeline: a donating backend produces the same outputs
        and logical stats as the non-donating one, for both a flat
        fused loop and a nested one."""
        be_d = JaxDeviceBackend(donate=True)
        be_n = JaxDeviceBackend(donate=False)
        p = _nested_prog(2, 3) if nested else _loop_prog(iters=5)
        pl = plan(p)
        out_d, s_d = execute(pl, mode="compiled", backend=be_d)
        out_n, s_n = execute(pl, mode="compiled", backend=be_n)
        np.testing.assert_array_equal(out_d["out"], out_n["out"])
        assert s_d.transfer_counts() == s_n.transfer_counts()
        assert s_d.fused_launches == s_n.fused_launches == 1


class TestReleaseGroups:
    def _two_group_prog(self):
        p = Program("two_groups")
        p.bind("a", np.arange(8, dtype=np.float32))
        p.bind("b", np.arange(8, dtype=np.float32) + 100.0)
        p.offload(lambda xp, a: {"x": a * 2.0}, reads=("a",),
                  writes=("x",), name="k0")
        p.offload(lambda xp, b: {"y": b + 1.0}, reads=("b",),
                  writes=("y",), name="k1")
        p.host(lambda xp, x, y: {"o": x + y}, reads=("x", "y"),
               writes=("o",), name="c")
        p.set_outputs("o")
        return p

    def test_release_frees_only_its_group(self):
        """A Release(group=0) moved before group 1's callsite must leave
        group 1's device-resident input alone.  (The old do_release freed
        EVERY group's buffers at the first Release, which made this plan
        raise 'not on device' at k1.)"""
        p = self._two_group_prog()
        pl = plan(p)
        assert len(pl.groups) == 2
        rel0 = next(op for op in pl.ops if op.kind == "directive"
                    and isinstance(op.directive, Release)
                    and op.directive.group == 0)
        k1_pos = next(i for i, op in enumerate(pl.ops)
                      if op.kind == "block"
                      and p.blocks[op.block_idx].name == "k1")
        pl.ops.remove(rel0)
        # at k1's callsite b (group 1) is already device-resident: the
        # old release-everything behaviour freed it here and k1 raised
        # "reads 'b': not on device"
        pl.ops.insert(k1_pos, rel0)
        oracle = run_host_oracle(p)
        for mode in ("interpreted", "compiled"):
            out, _ = execute(pl, mode=mode)
            np.testing.assert_allclose(out["o"], oracle["o"], rtol=1e-6)

    def test_group_vars_resolution(self):
        from repro.core.executor import group_vars
        p = self._two_group_prog()
        pl = plan(p)
        assert group_vars(pl, 0) == {"a", "x"}
        assert group_vars(pl, 1) == {"b", "y"}


class TestNaiveSyncPerCallsite:
    def test_single_sync_for_multi_output_block(self):
        p = Program()
        p.bind("A", np.ones((8, 8), np.float32))
        p.offload(lambda xp, A: {"S": A.sum(axis=0), "P": A * 2.0},
                  reads=("A",), writes=("S", "P"), name="k")
        p.host(lambda xp, S, P: {"o": S + P.sum(axis=0)},
               reads=("S", "P"), writes=("o",), name="c")
        p.set_outputs("o")
        pl = naive_plan(p)
        s = transfer_summary(pl)
        assert s["stores"] == 2
        assert s["syncs"] == 1            # per callsite, not per output
        _, stats = execute(pl)
        assert stats.syncs == 1
        assert stats.d2h_transfers == 2

    def test_naive_syncs_equal_storing_callsites(self):
        p, _ = build("3mm", n=16)
        pl = naive_plan(p)
        stores = pl.directives(DelegateStore)
        syncs = pl.directives(Synchronize)
        assert len(syncs) == len({d.block_idx for d in syncs})
        assert len(syncs) == 3 and len(stores) == 3


class TestOracleOutputContract:
    def test_empty_outputs_returns_empty_like_execute(self):
        p = Program()
        p.bind("a", np.ones((4,), np.float32))
        p.offload(lambda xp, a: {"b": a * 2.0}, reads=("a",),
                  writes=("b",), name="k")
        # no set_outputs: nothing is requested back on the host
        assert run_host_oracle(p) == {}
        out, _ = execute(plan(p))
        assert out == {}

    def test_oracle_keys_match_declared_outputs(self):
        p = _loop_prog(iters=2)
        oracle = run_host_oracle(p)
        assert set(oracle) == set(p.outputs)
