"""Unit tests for the roofline HLO analysis: while-loop trip-count
multipliers, ring-volume collective accounting, dot-FLOP counting."""
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import (analytic_hbm_bytes, analytic_model_flops,
                                     collective_bytes, dot_flops, parse_hlo,
                                     roofline_terms)

SYNTHETIC_HLO = """
HloModule test

%body (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256] get-tuple-element(%p), index=1
  %w = f32[256,256] constant(0)
  %y = f32[128,256] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256] all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add.1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%i2, %ar)
}

%cond (arg: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(48)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[128,256]) -> f32[128,256] {
  %x0 = f32[128,256] parameter(0)
  %g = f32[128,4096] all-gather(%x0), replica_groups=[16,16]<=[256], dimensions={1}
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]) tuple(%zero, %x0)
  %loop = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[128,256] get-tuple-element(%loop), index=1
}
"""


def test_while_trip_count_multiplier():
    mod = parse_hlo(SYNTHETIC_HLO)
    assert mod.entry == "main"
    assert mod.multipliers["main"] == 1.0
    assert mod.multipliers["body"] == 48.0


def test_dot_flops_with_loop_multiplier():
    mod = parse_hlo(SYNTHETIC_HLO)
    # one dot inside the 48-trip loop: 2 * 128*256 * 256 * 48
    want = 2 * 128 * 256 * 256 * 48
    assert dot_flops(mod) == want


def test_collective_ring_volume_accounting():
    mod = parse_hlo(SYNTHETIC_HLO)
    stats = collective_bytes(mod)
    n = 16
    ar_tensor = 128 * 256 * 4
    assert stats["all-reduce"]["count"] == 48
    np.testing.assert_allclose(stats["all-reduce"]["bytes"],
                               48 * 2 * (n - 1) / n * ar_tensor)
    ag_result = 128 * 4096 * 4
    np.testing.assert_allclose(stats["all-gather"]["bytes"],
                               (n - 1) / n * ag_result)
    assert stats["all-gather"]["count"] == 1


def test_model_flops_dense_vs_moe():
    dense = get_config("internlm2-20b")
    moe = get_config("qwen3-moe-30b-a3b")
    sh = SHAPES["train_4k"]
    f_dense = analytic_model_flops(dense, sh)
    f_moe = analytic_model_flops(moe, sh)
    # 6 N D is the dominant term
    assert f_dense > 6 * 19e9 * sh.global_batch * sh.seq_len
    # MoE counts ACTIVE params only (3.3B not 30B)
    assert f_moe < 6 * 5e9 * sh.global_batch * sh.seq_len


def test_decode_memory_model_kv_quant_halves():
    cfg = get_config("command-r-35b")
    sh = SHAPES["decode_32k"]
    full = analytic_hbm_bytes(cfg, sh, 256, kv_bytes=2)
    quant = analytic_hbm_bytes(cfg, sh, 256, kv_bytes=1)
    # params term is shared; the KV term halves
    assert quant < full
    assert (full - quant) > 0.3 * full  # KV dominates at 32k × 128


def test_roofline_terms_bottleneck_selection():
    cfg = get_config("internlm2-20b")
    sh = SHAPES["train_4k"]
    out = roofline_terms(cfg, sh, 256, SYNTHETIC_HLO)
    assert out["bottleneck"] in ("compute_s", "memory_s", "collective_s")
    assert 0 <= out["roofline_fraction"]
    assert out["model_flops"] > 0


def test_offload_cost_terms_price_collectives():
    """ISSUE 9: wire bytes of GSPMD collectives are priced against
    ici_bw beside the PCIe terms and added to the predicted sum."""
    from repro.roofline.analysis import HW, offload_cost_terms
    base = offload_cost_terms(1e6, 1e6, 2, 1, 1e9, 1e7)
    with_coll = offload_cost_terms(1e6, 1e6, 2, 1, 1e9, 1e7,
                                   coll_bytes=5e8)
    assert base["collective_s"] == 0.0
    assert with_coll["collective_s"] == 5e8 / HW["ici_bw"]
    assert with_coll["predicted_s"] - base["predicted_s"] == \
        with_coll["collective_s"]
    fast = offload_cost_terms(1e6, 1e6, 2, 1, 1e9, 1e7, coll_bytes=5e8,
                              hw={**HW, "ici_bw": HW["ici_bw"] * 10})
    assert fast["collective_s"] < with_coll["collective_s"]


def test_fit_recovers_ici_bw():
    """fit_offload_constants must recover the interconnect bandwidth
    from rows whose times were synthesized with a known ici_bw."""
    from repro.roofline.analysis import HW, fit_offload_constants
    rng = np.random.default_rng(0)
    true = dict(HW)
    true["ici_bw"] = 7.5e9
    rows = []
    for _ in range(40):
        pcie = float(rng.uniform(1e6, 1e9))
        disp = int(rng.integers(1, 20))
        syncs = int(rng.integers(0, 10))
        flops = float(rng.uniform(1e8, 1e12))
        kb = float(rng.uniform(1e6, 1e9))
        coll = float(rng.uniform(1e6, 1e9))
        t = (pcie / true["pcie_bw"]
             + disp * true["launch_overhead_s"]
             + syncs * true["sync_overhead_s"]
             + max(flops / true["peak_flops_bf16"], kb / true["hbm_bw"])
             + coll / true["ici_bw"])
        rows.append({"h2d_bytes": pcie / 2, "d2h_bytes": pcie / 2,
                     "dispatches": disp, "syncs": syncs, "flops": flops,
                     "kernel_bytes": kb, "coll_bytes": coll,
                     "measured_s": t})
    fitted = fit_offload_constants(rows)
    assert fitted["ici_bw"] == pytest.approx(7.5e9, rel=0.05)
