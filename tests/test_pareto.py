"""Multi-objective tuning (ISSUE 10): Pareto frontier, per-objective
winners, and the cross-program cold-start predictor.

The frontier/winner properties run both on synthetic point clouds
(hypothesis, skipped where it is not installed) and on real tuning
tables; the predictor is validated hold-one-out on 3mm/gemm/mvt against
a DETERMINISTIC synthetic truth — the measurement hook is monkeypatched
to a fixed formula with a per-stream cost term the analytic model cannot
see (the same synthesized-ground-truth style as the calibration golden),
so "learned ranking beats analytic ranking on a never-seen program" is a
reproducible fact rather than a wall-clock coincidence.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (TuneCache, execute, pareto_front, run_host_oracle,
                        tune, winner_exec_kwargs)
from repro.core import tuner as tuner_mod
from repro.optim.offload import attention_step_program
from repro.polybench import build

# the hold-one-out trio of the acceptance criteria
_PROGS = ("table2_3mm", "gemm", "mvt")


def _build(name):
    if name == "table2_3mm":
        return build("3mm", n=16)[0]
    if name == "gemm":
        return build("gemm", n=16, iters=4)[0]
    return build(name, n=16)[0]


def _objectives(rec):
    m = rec.get("measured_s")
    return (float(m if m is not None else rec["predicted_s"]),
            float(rec["energy_j"]), float(rec["peak_bytes"]))


def _dominates(a, b):
    return all(x <= y for x, y in zip(a, b)) and any(
        x < y for x, y in zip(a, b))


def _fake_measure(pl, cfg, be, reps, placement=None):
    """Deterministic synthetic truth for one candidate: transfer bytes
    over a slow 4 GB/s link, fat per-dispatch/sync overheads, plus a
    per-stream setup cost the analytic model has NO term for — only the
    cross-program predictor (stream count is one of its features) can
    learn it."""
    c = tuner_mod.predict_cost(pl, cfg, {})
    truth = ((c["h2d_bytes"] + c["d2h_bytes"]) / 4e9
             + 8e-4 * c["dispatches"] + 2e-4 * c["syncs"]
             + 2.5e-4 * cfg.n_streams)
    return truth, 0.0


class TestParetoFrontier:
    def test_hypothesis_front_mutually_nondominated(self):
        pytest.importorskip(
            "hypothesis", reason="property tests need hypothesis "
            "(pip install -r requirements-dev.txt)")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        coords = st.tuples(*([st.integers(0, 5)] * 3))

        @settings(max_examples=200, deadline=None)
        @given(st.lists(coords, min_size=1, max_size=24))
        def run(points):
            front = pareto_front(points)
            assert front, "a non-empty set has a non-dominated point"
            chosen = [points[i] for i in front]
            for a in chosen:
                assert not any(_dominates(b, a) for b in chosen)
            # everything off the frontier is dominated by something on it
            for i, p in enumerate(points):
                if i not in front:
                    assert any(_dominates(c, p) for c in chosen)
            # the lexicographic minimum of every axis order sits on it
            for axis in range(3):
                lex = min(points,
                          key=lambda p: (p[axis],) + tuple(p))
                assert any(points[i] == lex for i in front)

        run()

    def test_real_table_front_nondominated_and_contains_time_winner(self):
        pl = tune(_build("table2_3mm"), backend="numpy", measure=False,
                  cache=False, use_calibration=False)
        t = pl.meta["tuning"]
        by_label = {c["label"]: c for c in t["candidates"]}
        pts = {e["label"]: (e["time_s"], e["energy_j"], e["peak_bytes"])
               for e in t["pareto"]}
        assert pts, "frontier is never empty"
        for a in pts.values():
            assert not any(_dominates(b, a) for b in pts.values())
        # the reported points echo the candidate records exactly
        for label, pt in pts.items():
            assert pt == _objectives(by_label[label])
        # every per-objective winner is on the frontier (frontier points
        # collapse coordinate-duplicates, so compare by coordinates)
        for obj, label in t["winners"].items():
            assert _objectives(by_label[label]) in list(pts.values()), obj
        assert t["objective"] == "time"

    def test_attn_step_has_frontier_with_distinct_winners(self):
        """The acceptance benchmark: flash-attention's tile axis trades
        time (big block_q → fewer passes) against on-chip residency
        (small tiles) — ≥2 non-dominated points, and the time-optimal
        and memory-optimal winners are different plans."""
        pl = tune(attention_step_program(n_steps=1), backend="numpy",
                  measure=False, cache=False, use_calibration=False)
        t = pl.meta["tuning"]
        assert len(t["pareto"]) >= 2
        assert t["winners"]["time"] != t["winners"]["memory"]
        # the memory winner really does hold fewer peak bytes
        by_label = {c["label"]: c for c in t["candidates"]}
        assert (by_label[t["winners"]["memory"]]["peak_bytes"]
                < by_label[t["winners"]["time"]]["peak_bytes"])

    def test_objective_selects_winner_and_weighted_mix(self):
        prog = attention_step_program(n_steps=1)
        for obj in ("energy", "memory"):
            pl = tune(prog, backend="numpy", measure=False, cache=False,
                      use_calibration=False, objective=obj)
            t = pl.meta["tuning"]
            assert t["objective"] == obj
            assert t["chosen"] == t["winners"][obj]
        with pytest.raises(ValueError):
            tune(prog, backend="numpy", measure=False, cache=False,
                 objective="joules")
        pl = tune(prog, backend="numpy", measure=False, cache=False,
                  use_calibration=False,
                  objective={"time": 0.5, "memory": 0.5})
        assert pl.meta["tuning"]["chosen"] in {
            c["label"] for c in pl.meta["tuning"]["candidates"]}

    @pytest.mark.parametrize("name", _PROGS)
    def test_energy_objective_executes_allclose_to_oracle(self, name):
        """An energy-selected plan is still a CORRECT plan: executing it
        through winner_exec_kwargs reproduces the host oracle."""
        if name == "table2_3mm":
            prog, inputs = build("3mm", n=16)
        elif name == "gemm":
            prog, inputs = build("gemm", n=16, iters=4)
        else:
            prog, inputs = build(name, n=16)
        pl = tune(prog, backend="numpy", measure=False, cache=False,
                  use_calibration=False, objective="energy")
        want = run_host_oracle(prog, inputs)
        got, _ = execute(pl, inputs, **winner_exec_kwargs(pl, "numpy"))
        for out in prog.outputs:
            np.testing.assert_allclose(got[out], want[out], rtol=1e-5,
                                       atol=1e-6)


class TestColdStartPredictor:
    def _tune_measured(self, name, tc, **kw):
        return tune(_build(name), backend="numpy", reps=1, cache=tc,
                    calibrate=False, use_calibration=False, **kw)

    @pytest.mark.parametrize("held_out", _PROGS)
    def test_holdout_ranking_no_worse_than_analytic(self, held_out,
                                                    tmp_path, monkeypatch):
        """Fit from the other two programs' measured rows, price the
        held-out grid, and the learned ranking must be at least as
        rank-correlated with (synthetic) truth as the uncalibrated
        analytic model — the acceptance gate, on all three rotations."""
        monkeypatch.setattr(tuner_mod, "_measure", _fake_measure)
        tc = TuneCache(tmp_path / "hold")
        for name in _PROGS:
            if name != held_out:
                self._tune_measured(name, tc)
        pl = self._tune_measured(held_out, tc)
        rec = pl.meta["tuning"]["predictor"]
        assert rec["source"] == "fit"
        assert rec["n_programs"] == 2
        assert rec["accepted"] is True
        assert (rec["rank_corr_predictor"]
                >= rec["rank_corr_analytic"])

    def test_holdout_strictly_beats_analytic_on_stream_term(self,
                                                            tmp_path,
                                                            monkeypatch):
        """mvt's grid separates stream counts into distinct execution
        classes, and the synthetic truth charges per stream — a term the
        analytic model cannot express, so the learned ranking is
        STRICTLY better there."""
        monkeypatch.setattr(tuner_mod, "_measure", _fake_measure)
        tc = TuneCache(tmp_path / "strict")
        for name in ("table2_3mm", "gemm"):
            self._tune_measured(name, tc)
        pl = self._tune_measured("mvt", tc)
        rec = pl.meta["tuning"]["predictor"]
        assert rec["accepted"] is True
        assert (rec["rank_corr_predictor"]
                > rec["rank_corr_analytic"])

    def test_cold_start_prices_unmeasured_grid(self, tmp_path,
                                               monkeypatch):
        """A program never measured at all (measure=False) still gets
        predictor prices on every candidate, and with zero measurements
        the chosen winner comes from the learned model."""
        monkeypatch.setattr(tuner_mod, "_measure", _fake_measure)
        tc = TuneCache(tmp_path / "cold")
        for name in ("table2_3mm", "gemm"):
            self._tune_measured(name, tc)
        pl = tune(_build("mvt"), backend="numpy", measure=False, cache=tc,
                  calibrate=False, use_calibration=False)
        t = pl.meta["tuning"]
        rec = t["predictor"]
        assert rec["used_for_ranking"] is True
        valid = [c for c in t["candidates"] if c["valid"]]
        assert all(c.get("predictor_s") is not None for c in valid)
        assert t["chosen"] == min(
            valid, key=lambda c: (c["predictor_s"], c["rank"]))["label"]

    def test_no_training_rows_no_predictor(self, tmp_path):
        tc = TuneCache(tmp_path / "empty")
        pl = tune(_build("gemm"), backend="numpy", measure=False, cache=tc,
                  use_calibration=False)
        rec = pl.meta["tuning"]["predictor"]
        assert rec["source"] is None
        assert rec["used_for_ranking"] is False
        assert pl.meta["tuning"]["chosen"] == next(
            c["label"] for c in pl.meta["tuning"]["candidates"]
            if c["valid"] and c["rank"] == 1)
