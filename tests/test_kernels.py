"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


@pytest.mark.parametrize("B,S,K,G,D", [
    (1, 32, 1, 1, 8),
    (2, 64, 2, 4, 16),
    (1, 128, 4, 2, 32),
    (2, 64, 1, 8, 64),      # MQA-style
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_sweep(B, S, K, G, D, dtype, tol):
    q = _rand((B, S, K, G, D), dtype)
    k = _rand((B, S, K, D), dtype)
    v = _rand((B, S, K, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_local_window(window):
    B, S, K, G, D = 2, 64, 2, 2, 16
    q = _rand((B, S, K, G, D), jnp.float32)
    k = _rand((B, S, K, D), jnp.float32)
    v = _rand((B, S, K, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,T,D,block", [
    (1, 32, 8, 8), (2, 128, 24, 32), (3, 64, 16, 64),
])
def test_rglru_scan_sweep(B, T, D, block):
    a = jnp.asarray(RNG.uniform(0.4, 0.999, (B, T, D)).astype(np.float32))
    b = _rand((B, T, D), jnp.float32)
    out = ops.rglru_scan(a, b, block_t=block, interpret=True)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,T,H,hs,block", [
    (1, 32, 1, 8, 8), (2, 64, 3, 8, 16), (1, 128, 2, 16, 32),
])
def test_wkv6_sweep(B, T, H, hs, block):
    r = _rand((B, T, H, hs), jnp.float32)
    k = _rand((B, T, H, hs), jnp.float32)
    v = _rand((B, T, H, hs), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.2, 0.99, (B, T, H, hs)).astype(np.float32))
    u = _rand((H, hs), jnp.float32)
    o, s = ops.wkv6(r, k, v, w, u, block_t=block, interpret=True)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, T, hs)
    uu = jnp.broadcast_to(u[None], (B, H, hs)).reshape(B * H, hs)
    o_ref, s_ref = ref.wkv6_ref(fold(r), fold(k), fold(v), fold(w), uu)
    o_ref = o_ref.reshape(B, H, T, hs).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(s.reshape(B * H, hs, hs)), np.asarray(s_ref),
        rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(8, 32), (4, 16, 48), (128, 64)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_rmsnorm_sweep(shape, dtype, tol):
    x = _rand(shape, dtype)
    w = _rand(shape[-1:], dtype)
    out = ops.rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grad_matches_ref():
    """Kernel path is differentiable (interpret mode) and grads match."""
    B, S, K, G, D = 1, 32, 2, 2, 8
    q = _rand((B, S, K, G, D), jnp.float32)
    k = _rand((B, S, K, D), jnp.float32)
    v = _rand((B, S, K, D), jnp.float32)

    def f_kernel(q):
        return ops.flash_attention(q, k, v, causal=True, block_q=16,
                                   block_k=16, interpret=True).sum()

    def f_ref(q):
        return ref.flash_attention_ref(q, k, v, causal=True).sum()

    g1 = jax.grad(f_kernel)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-4)
