"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles,
plus full coverage of the tile-variant registry (``kernels.variants``):
every enumerable variant of every kernel must launch and match the
reference, and invalid tiles must be rejected before launch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, variants

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


@pytest.mark.parametrize("B,S,K,G,D", [
    (1, 32, 1, 1, 8),
    (2, 64, 2, 4, 16),
    (1, 128, 4, 2, 32),
    (2, 64, 1, 8, 64),      # MQA-style
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_sweep(B, S, K, G, D, dtype, tol):
    q = _rand((B, S, K, G, D), dtype)
    k = _rand((B, S, K, D), dtype)
    v = _rand((B, S, K, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_local_window(window):
    B, S, K, G, D = 2, 64, 2, 2, 16
    q = _rand((B, S, K, G, D), jnp.float32)
    k = _rand((B, S, K, D), jnp.float32)
    v = _rand((B, S, K, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,T,D,block", [
    (1, 32, 8, 8), (2, 128, 24, 32), (3, 64, 16, 64),
])
def test_rglru_scan_sweep(B, T, D, block):
    a = jnp.asarray(RNG.uniform(0.4, 0.999, (B, T, D)).astype(np.float32))
    b = _rand((B, T, D), jnp.float32)
    out = ops.rglru_scan(a, b, block_t=block, interpret=True)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,T,H,hs,block", [
    (1, 32, 1, 8, 8), (2, 64, 3, 8, 16), (1, 128, 2, 16, 32),
])
def test_wkv6_sweep(B, T, H, hs, block):
    r = _rand((B, T, H, hs), jnp.float32)
    k = _rand((B, T, H, hs), jnp.float32)
    v = _rand((B, T, H, hs), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.2, 0.99, (B, T, H, hs)).astype(np.float32))
    u = _rand((H, hs), jnp.float32)
    o, s = ops.wkv6(r, k, v, w, u, block_t=block, interpret=True)
    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, T, hs)
    uu = jnp.broadcast_to(u[None], (B, H, hs)).reshape(B * H, hs)
    o_ref, s_ref = ref.wkv6_ref(fold(r), fold(k), fold(v), fold(w), uu)
    o_ref = o_ref.reshape(B, H, T, hs).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(s.reshape(B * H, hs, hs)), np.asarray(s_ref),
        rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(8, 32), (4, 16, 48), (128, 64)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_rmsnorm_sweep(shape, dtype, tol):
    x = _rand(shape, dtype)
    w = _rand(shape[-1:], dtype)
    out = ops.rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grad_matches_ref():
    """Kernel path is differentiable (interpret mode) and grads match."""
    B, S, K, G, D = 1, 32, 2, 2, 8
    q = _rand((B, S, K, G, D), jnp.float32)
    k = _rand((B, S, K, D), jnp.float32)
    v = _rand((B, S, K, D), jnp.float32)

    def f_kernel(q):
        return ops.flash_attention(q, k, v, causal=True, block_q=16,
                                   block_k=16, interpret=True).sum()

    def f_ref(q):
        return ref.flash_attention_ref(q, k, v, causal=True).sum()

    g1 = jax.grad(f_kernel)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-4)


# --- tile-variant registry coverage (ISSUE 6) ------------------------------
#
# One representative operand set per kernel, sized so the declared grids
# yield several distinct variants after clamp+dedup.  EVERY registry
# variant must launch and agree with the reference.

_FLASH_SHAPES = ((1, 128, 1, 2, 8), (1, 128, 1, 8), (1, 128, 1, 8))
_WKV6_SHAPES = ((1, 128, 2, 8),) * 4 + ((2, 8),)
_RGLRU_SHAPES = ((1, 256, 8),) * 2
_RMSNORM_SHAPES = ((128, 32), (32,))


def _variant_cases():
    cases = []
    for kernel, shapes in (("flash_attention", _FLASH_SHAPES),
                           ("wkv6", _WKV6_SHAPES),
                           ("rglru_scan", _RGLRU_SHAPES),
                           ("rmsnorm", _RMSNORM_SHAPES)):
        for v in variants.variants_for(kernel, shapes):
            cases.append(pytest.param(kernel, shapes, v, id=v.label))
    return cases


def test_registry_covers_every_kernel():
    assert set(variants.kernel_names()) == {
        "flash_attention", "wkv6", "rglru_scan", "rmsnorm"}
    for kernel, shapes in (("flash_attention", _FLASH_SHAPES),
                           ("wkv6", _WKV6_SHAPES),
                           ("rglru_scan", _RGLRU_SHAPES),
                           ("rmsnorm", _RMSNORM_SHAPES)):
        vs = variants.variants_for(kernel, shapes)
        assert len(vs) >= 2, f"{kernel}: want >= 2 distinct variants"
        assert len({v.params for v in vs}) == len(vs)


@pytest.mark.parametrize("kernel,shapes,variant", _variant_cases())
def test_every_registry_variant_matches_ref(kernel, shapes, variant):
    kw = dict(variant.kwargs(), interpret=True)
    if kernel == "flash_attention":
        q = _rand(shapes[0], jnp.float32)
        k = _rand(shapes[1], jnp.float32)
        v = _rand(shapes[2], jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True, **kw)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    elif kernel == "wkv6":
        B, T, H, hs = shapes[0]
        r = _rand(shapes[0], jnp.float32)
        k = _rand(shapes[1], jnp.float32)
        v = _rand(shapes[2], jnp.float32)
        w = jnp.asarray(RNG.uniform(0.2, 0.99, shapes[3]).astype(np.float32))
        u = _rand(shapes[4], jnp.float32)
        o, s = ops.wkv6(r, k, v, w, u, **kw)
        def fold(t):
            return t.transpose(0, 2, 1, 3).reshape(B * H, T, hs)
        uu = jnp.broadcast_to(u[None], (B, H, hs)).reshape(B * H, hs)
        o_ref, s_ref = ref.wkv6_ref(fold(r), fold(k), fold(v), fold(w), uu)
        o_ref = o_ref.reshape(B, H, T, hs).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(s.reshape(B * H, hs, hs)), np.asarray(s_ref),
            rtol=2e-4, atol=2e-4)
    elif kernel == "rglru_scan":
        a = jnp.asarray(RNG.uniform(0.4, 0.999, shapes[0])
                        .astype(np.float32))
        b = _rand(shapes[1], jnp.float32)
        out = ops.rglru_scan(a, b, **kw)
        want = ref.rglru_scan_ref(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    else:
        x = _rand(shapes[0], jnp.float32)
        w = _rand(shapes[1], jnp.float32)
        out = ops.rmsnorm(x, w, **kw)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestVariantValidation:
    def test_invalid_tiles_rejected(self):
        """A non-dividing tile (after clamping) is refused both by
        validate_variant (None) and by kernel_roofline (ValueError) —
        the tuner never enumerates or prices an unlaunchable tile."""
        bad = (((1, 96, 1, 2, 8), (1, 96, 1, 8), (1, 96, 1, 8)),)
        assert variants.validate_variant(
            "flash_attention", bad[0], {"block_q": 64, "block_k": 32}) \
            is None
        with pytest.raises(ValueError):
            variants.kernel_roofline(
                "flash_attention", {"block_q": 64, "block_k": 32}, bad[0])
        assert variants.validate_variant(
            "wkv6", ((1, 96, 2, 8),) * 4 + ((2, 8),), {"block_t": 64}) \
            is None
        assert variants.validate_variant(
            "rglru_scan", ((1, 96, 8),) * 2, {"block_t": 64}) is None

    def test_clamped_variants_dedupe(self):
        """block_q=256 on a 128-token sequence collapses onto block_q=128:
        one launch, one enumerated variant."""
        vs = variants.variants_for("flash_attention", _FLASH_SHAPES)
        assert all(dict(v.params)["block_q"] <= 128 for v in vs)
        assert len(vs) == 4          # {64,128} x {64,128} after dedup

    def test_rmsnorm_canon_mirrors_ops_halving(self):
        """ops.rmsnorm halves block_rows until it divides; the registry's
        canonicalisation must land on the same launched tile."""
        v = variants.validate_variant("rmsnorm", ((96, 32), (32,)),
                                      {"block_rows": 256})
        assert dict(v.params)["block_rows"] == 96 // 32 or \
            96 % dict(v.params)["block_rows"] == 0

    def test_roofline_bytes_vary_across_tiles(self):
        """The whole point of the kernel axis: kernel_s must differ across
        tile candidates.  Flash attention re-reads K/V once per q tile, so
        smaller block_q => more bytes."""
        f64, b64 = variants.kernel_roofline(
            "flash_attention", {"block_q": 64, "block_k": 64},
            _FLASH_SHAPES)
        f128, b128 = variants.kernel_roofline(
            "flash_attention", {"block_q": 128, "block_k": 64},
            _FLASH_SHAPES)
        assert f64 == f128           # same math
        assert b64 > b128            # more K/V traffic with smaller tiles

    def test_bind_variant_identity_stable(self):
        """Bound callables are memoized: backend jit caches key on fn
        identity, so the same (fn, params) must give the SAME object."""
        fn = ops.rmsnorm
        p = (("block_rows", 64),)
        assert variants.bind_variant(fn, p) is variants.bind_variant(fn, p)
        assert variants.bind_variant(fn, p).keywords == {"block_rows": 64}
