"""Persistent tuning cache + measured calibration + dominance pruning
(ISSUE 5).

Acceptance criteria under test:

  * a second ``plan(p, policy="auto")`` call on the same program +
    backend performs ZERO measurements (cache hit) yet returns a
    ``plan.meta["tuning"]`` table identical to the fresh run,
  * the fingerprint misses on a program edit, a backend swap, or a
    cost-model version bump (stale entries are evicted, not reused),
  * calibration: least squares on the (predicted-terms, measured-time)
    table recovers the generating constants and demonstrably improves
    the predicted-vs-measured rank correlation on the golden 3mm table,
  * dominance pruning merges execution-identical configs (donate on a
    non-donating backend, fuse with no fusable loops, streams with < 2
    groups) into one measurement while the table still enumerates the
    full grid,
  * measured candidates run on a physically matching backend
    (``Backend.variant``: real stream count, real donation flag),
  * the CI tuning-regression gate agrees with the checked-in baseline.
"""
import json
import pathlib
import sys

import numpy as np
import pytest

from repro.core import (COST_MODEL_VERSION, JaxDeviceBackend,
                        NumpyHostBackend, Program, TuneCache,
                        device_class_key, get_backend, plan,
                        program_fingerprint, tune)
from repro.core import tunecache as tunecache_mod
from repro.polybench import build, build_3mm
from repro.roofline.analysis import (HW, fit_offload_constants,
                                     offload_cost_terms, rank_correlation)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
CAL_GOLDEN = json.loads((GOLDEN_DIR / "calibration_3mm.json").read_text())


def _auto(p, **kw):
    kw.setdefault("backend", "numpy")
    kw.setdefault("reps", 1)
    return plan(p, policy="auto", **kw)


class TestCacheHit:
    def test_second_call_zero_measurements_identical_table(self):
        """THE acceptance criterion: hit returns the stored winner with
        no re-measurement and a byte-identical ranked table."""
        p, _ = build_3mm(n=16)
        pl1 = _auto(p)
        info1 = pl1.meta["tuning_cache"]
        assert info1["hit"] is False and info1["measurements"] > 0
        pl2 = _auto(p)
        info2 = pl2.meta["tuning_cache"]
        assert info2["hit"] is True and info2["measurements"] == 0
        assert pl2.meta["tuning"] == pl1.meta["tuning"]
        assert tuple(pl2.ops) == tuple(pl1.ops)
        assert pl2.meta["fuse_loops"] == pl1.meta["fuse_loops"]
        assert pl2.meta["donate"] == pl1.meta["donate"]
        assert pl2.meta["optimize"] == pl1.meta["optimize"]

    def test_refresh_forces_remeasure(self):
        p, _ = build_3mm(n=16)
        _auto(p)
        pl = _auto(p, refresh=True)
        assert pl.meta["tuning_cache"]["hit"] is False
        assert pl.meta["tuning_cache"]["measurements"] > 0

    def test_explicit_cache_object(self, tmp_path):
        p, _ = build_3mm(n=16)
        tc = TuneCache(tmp_path / "explicit")
        pl1 = tune(p, backend="numpy", reps=1, cache=tc)
        assert pl1.meta["tuning_cache"]["path"] == str(tc.path)
        assert list(tc.path.glob("*.json"))
        pl2 = tune(p, backend="numpy", reps=1, cache=tc)
        assert pl2.meta["tuning_cache"]["hit"] is True

    def test_cache_false_disables(self):
        p, _ = build_3mm(n=16)
        _auto(p)                              # seeds the env-default cache
        pl = _auto(p, cache=False)
        assert pl.meta["tuning_cache"]["hit"] is False
        assert pl.meta["tuning_cache"]["path"] is None
        assert pl.meta["tuning_cache"]["measurements"] > 0

    def test_measure_off_bypasses_cache(self):
        """A prediction-only call must not answer with (or overwrite) a
        measured table."""
        p, _ = build_3mm(n=16)
        _auto(p)
        pl = tune(p, backend="numpy", measure=False)
        assert all(c["measured_s"] is None
                   for c in pl.meta["tuning"]["candidates"])
        # and the measured entry is still there afterwards
        assert _auto(p).meta["tuning_cache"]["hit"] is True

    def test_protocol_change_misses_and_variants_coexist(self):
        """A different measurement protocol misses — into its OWN slot:
        alternating protocol variants must not evict-thrash each other."""
        p, _ = build_3mm(n=16)
        _auto(p)
        pl = _auto(p, top_k=1)                # different measurement protocol
        assert pl.meta["tuning_cache"]["hit"] is False
        assert _auto(p).meta["tuning_cache"]["hit"] is True
        assert _auto(p, top_k=1).meta["tuning_cache"]["hit"] is True


class TestInvalidation:
    def test_program_edit_invalidates(self, tmp_path):
        """Same program name, edited block body → stale fingerprint is
        evicted and the slot re-measured (not silently reused)."""
        def make(scale):
            p = Program("editme")
            p.bind("A", np.ones((8, 8), np.float32))
            p.offload(lambda xp, A: {"B": A * scale}, reads=("A",),
                      writes=("B",), name="k")
            p.host(lambda xp, B: {"o": B[:1]}, reads=("B",),
                   writes=("o",), name="c")
            p.set_outputs("o")
            return p

        def tuning_slots(tc):
            # the measured-table slots only: a measured run also writes
            # the per-device-class store (rows/calibration/predictor)
            return [f for f in tc.path.glob("*.json")
                    if not f.name.startswith("devclass--")]

        tc = TuneCache(tmp_path / "edit")
        tune(make(2.0), backend="numpy", reps=1, cache=tc)
        assert len(tuning_slots(tc)) == 1
        pl = tune(make(3.0), backend="numpy", reps=1, cache=tc)
        assert pl.meta["tuning_cache"]["hit"] is False
        assert pl.meta["tuning_cache"]["measurements"] > 0
        # the slot was overwritten, not duplicated
        assert len(tuning_slots(tc)) == 1

    def test_closure_captured_array_resize_invalidates(self):
        """A block body capturing an array (instead of binding it as an
        input) must fingerprint its SHAPE: numpy's repr truncates large
        arrays shapelessly, so repr alone would alias a resized capture
        onto the stale entry."""
        def make(n):
            w = np.ones((n,), np.float32)
            p = Program("capture")
            p.bind("x", np.ones((4,), np.float32))
            p.offload(lambda xp, x: {"y": x * xp.sum(w[:1])},
                      reads=("x",), writes=("y",), name="k")
            p.host(lambda xp, y: {"o": y}, reads=("y",), writes=("o",),
                   name="c")
            p.set_outputs("o")
            return p

        assert program_fingerprint(make(2000)) != \
            program_fingerprint(make(4000))
        assert program_fingerprint(make(2000)) == \
            program_fingerprint(make(2000))

    def test_env_disable_sentinel_not_a_directory(self, monkeypatch,
                                                  tmp_path):
        """REPRO_TUNE_CACHE=off disables default_cache(); a direct
        TuneCache() must not mistake the sentinel for a path and create
        a literal ./off directory."""
        from repro.core import default_cache
        monkeypatch.chdir(tmp_path)
        monkeypatch.setenv("REPRO_TUNE_CACHE", "off")
        assert default_cache() is None
        monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
        assert TuneCache().path == tmp_path / "xdg" / "repro" / "tunecache"
        assert not (tmp_path / "off").exists()

    def test_fingerprint_tracks_shapes_not_values(self):
        p16a, _ = build_3mm(n=16)
        p16b, _ = build_3mm(n=16, seed=1)     # same shapes, new values
        p8, _ = build_3mm(n=8)
        assert program_fingerprint(p16a) == program_fingerprint(p16b)
        assert program_fingerprint(p16a) != program_fingerprint(p8)

    def test_backend_swap_is_a_distinct_slot(self, tmp_path):
        p, _ = build_3mm(n=16)
        tc = TuneCache(tmp_path / "be")
        tune(p, backend="numpy", reps=1, cache=tc)
        pl = tune(p, backend="jax", reps=1, cache=tc)
        assert pl.meta["tuning_cache"]["hit"] is False
        # both entries coexist (different slots, no eviction)
        assert tune(p, backend="numpy", reps=1,
                    cache=tc).meta["tuning_cache"]["hit"] is True
        assert tune(p, backend="jax", reps=1,
                    cache=tc).meta["tuning_cache"]["hit"] is True

    def test_cost_model_version_bump_invalidates(self, monkeypatch):
        p, _ = build_3mm(n=16)
        _auto(p)
        monkeypatch.setattr(tunecache_mod, "COST_MODEL_VERSION",
                            COST_MODEL_VERSION + 1000)
        pl = _auto(p)
        assert pl.meta["tuning_cache"]["hit"] is False
        assert pl.meta["tuning_cache"]["measurements"] > 0

    def test_kernel_tag_part_of_fingerprint(self):
        """Tagging a block as a kernel changes how the tuner prices and
        launches it — the fingerprint must miss."""
        def make(kernel):
            p = Program("ktag")
            p.bind("x", np.ones((4, 4), np.float32))
            p.offload(lambda xp, x: {"y": x * 2.0}, reads=("x",),
                      writes=("y",), name="k", kernel=kernel)
            p.host(lambda xp, y: {"o": y}, reads=("y",), writes=("o",),
                   name="c")
            p.set_outputs("o")
            return p

        assert program_fingerprint(make(None)) != \
            program_fingerprint(make("rmsnorm"))
        assert program_fingerprint(make("rmsnorm")) == \
            program_fingerprint(make("rmsnorm"))


class TestLRUEviction:
    def _store_n(self, tc, n, fp="fp"):
        for i in range(n):
            tc.store(f"slot-{i:03d}", fp, {"i": i})

    def test_store_evicts_oldest_past_cap(self, tmp_path):
        tc = TuneCache(tmp_path / "lru", max_entries=4)
        import os
        for i in range(6):
            tc.store(f"slot-{i:03d}", "fp", {"i": i})
            # distinct mtimes even on coarse-grained filesystems
            os.utime(tc._slot_path(f"slot-{i:03d}"), (i, i))
        assert len(list(tc.path.glob("*.json"))) == 4
        # the oldest two are gone; the newest survive
        assert tc.lookup("slot-000", "fp") is None
        assert tc.lookup("slot-001", "fp") is None
        assert tc.lookup("slot-005", "fp") == {"i": 5}

    def test_lookup_touches_entry_lru_not_fifo(self, tmp_path):
        import os
        tc = TuneCache(tmp_path / "lru2", max_entries=2)
        tc.store("a", "fp", {"v": "a"})
        os.utime(tc._slot_path("a"), (1, 1))
        tc.store("b", "fp", {"v": "b"})
        os.utime(tc._slot_path("b"), (2, 2))
        assert tc.lookup("a", "fp") == {"v": "a"}   # touches a -> newest
        tc.store("c", "fp", {"v": "c"})             # evicts b, not a
        assert tc.lookup("a", "fp") == {"v": "a"}
        assert tc.lookup("b", "fp") is None
        assert tc.lookup("c", "fp") == {"v": "c"}

    def test_just_written_entry_never_evicted(self, tmp_path):
        tc = TuneCache(tmp_path / "lru3", max_entries=1)
        self._store_n(tc, 3)
        assert tc.lookup("slot-002", "fp") == {"i": 2}

    def test_env_var_sets_cap(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE_MAX", "3")
        tc = TuneCache(tmp_path / "lru4")
        assert tc.max_entries == 3
        monkeypatch.setenv("REPRO_TUNE_CACHE_MAX", "not-a-number")
        assert TuneCache(tmp_path / "lru5").max_entries == \
            tunecache_mod._DEFAULT_MAX_ENTRIES

    def test_cap_zero_disables_eviction(self, tmp_path):
        tc = TuneCache(tmp_path / "lru6", max_entries=0)
        self._store_n(tc, 5)
        assert len(list(tc.path.glob("*.json"))) == 5


class TestDominancePruning:
    def test_donate_and_fuse_merge_on_numpy_loopfree(self):
        """3mm is loop-free and numpy has no donation: the fuse and
        donate axes cannot change execution, so all four flag combos of
        each placement collapse into one measured class."""
        p, _ = build_3mm(n=16)
        pl = _auto(p)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        survivors = [c for c in valid if c["alias_of"] is None]
        assert all(not s["config"]["donate"] for s in survivors)
        assert pl.meta["tuning_cache"]["measurements"] == len(survivors)
        # the grid is still fully enumerated (paper's axes preserved;
        # 4 policies x 2 streams x 2 fuse x 2 donate since "pipeline")
        assert len(valid) == 64
        donate_recs = [c for c in valid if c["config"]["donate"]]
        assert donate_recs and all(c["alias_of"] for c in donate_recs)

    def test_fuse_distinct_with_fusable_loop(self):
        """gemm's iterated kernel CAN fuse: fuse on/off are different
        executions and must be measured separately."""
        p, _ = build("gemm", n=16, iters=4)
        pl = _auto(p)
        survivors = [c for c in pl.meta["tuning"]["candidates"]
                     if c["valid"] and c["alias_of"] is None]
        opt_fuse = {s["config"]["fuse_loops"] for s in survivors
                    if s["config"]["policy"] == "optimized"}
        assert opt_fuse == {True, False}

    def test_streams_merge_with_single_group(self):
        """3mm forms one directive group under the single-group policies
        → stream assignment is identical for any stream count → one
        class across the axis.  The pipeline policy is the designed
        exception: one group per stage makes the stream axis live."""
        p, _ = build_3mm(n=16)
        pl = _auto(p)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        streams_of_survivors = {c["config"]["n_streams"] for c in valid
                                if c["alias_of"] is None
                                and c["config"]["policy"] != "pipeline"}
        assert streams_of_survivors == {1}
        pipe_streams = {c["config"]["n_streams"] for c in valid
                        if c["alias_of"] is None
                        and c["config"]["policy"] == "pipeline"}
        assert len(pipe_streams) > 1     # 3 stage groups: streams are live

    def test_alias_records_share_class_numbers(self):
        p, _ = build_3mm(n=16)
        pl = _auto(p)
        valid = {c["label"]: c for c in pl.meta["tuning"]["candidates"]
                 if c["valid"]}
        for c in valid.values():
            if c["alias_of"]:
                surv = valid[c["alias_of"]]
                assert c["label"] in surv["aliases"]
                assert c["measured_s"] == surv["measured_s"]
                assert c["predicted_s"] == surv["predicted_s"]


class TestBackendVariant:
    def test_jax_variant_pool(self):
        be = JaxDeviceBackend()
        assert be.donate                 # donation is on by default (ISSUE 8)
        v3 = be.variant(n_streams=3)
        assert v3.n_streams == 3 and v3.donate == be.donate
        assert be.variant(n_streams=3) is v3          # memoized
        assert be.variant() is be
        # variant-of-variant folds back onto the original instance so
        # jit/lowering caches are shared across tuning calls
        assert v3.variant(n_streams=be.n_streams, donate=True) is be
        vn = be.variant(donate=False)                 # explicit opt-out
        assert not vn.donate and vn.n_streams == be.n_streams
        assert vn.variant(donate=True) is be

    def test_numpy_has_no_variants(self):
        be = NumpyHostBackend()
        assert be.variant(n_streams=4, donate=True) is be
        assert not be.supports_donation
        assert JaxDeviceBackend.supports_donation

    def test_measure_uses_physical_stream_count(self, monkeypatch):
        """A streams-3 candidate must be timed on a 3-queue backend, not
        folded onto the caller's 2-queue instance."""
        from repro.core import tuner as tuner_mod
        seen = []
        orig = tuner_mod._measure

        def spy(pl, cfg, be, reps, placement=None):
            v = be.variant(n_streams=cfg.n_streams, donate=cfg.donate)
            seen.append((cfg.n_streams, v.n_streams, cfg.donate,
                         getattr(v, "donate", False)))
            return orig(pl, cfg, be, reps, placement=placement)

        monkeypatch.setattr(tuner_mod, "_measure", spy)
        p, _ = build("gemm", n=8, iters=2)
        tune(p, backend="jax", reps=1, cache=False)
        assert seen
        for want_s, got_s, want_d, got_d in seen:
            assert got_s == want_s and got_d == want_d


class TestCalibration:
    def _golden_rows(self):
        return [dict(r) for r in CAL_GOLDEN["rows"]]

    def test_fit_recovers_generating_constants(self):
        """The golden table's measured times were synthesized from known
        constants; the least-squares fit must recover them."""
        fitted = fit_offload_constants(self._golden_rows())
        for k, v in CAL_GOLDEN["true_hw"].items():
            assert fitted[k] == pytest.approx(v, rel=1e-6), k

    def test_calibration_improves_rank_correlation(self):
        """Acceptance: calibration demonstrably improves the
        predicted-vs-measured rank correlation on the golden 3mm table."""
        rows = self._golden_rows()
        meas = [r["measured_s"] for r in rows]
        before = rank_correlation([r["predicted_s"] for r in rows], meas)
        fitted = fit_offload_constants(rows)
        hw2 = dict(HW)
        hw2.update(fitted)
        after_pred = [offload_cost_terms(
            r["h2d_bytes"], r["d2h_bytes"], r["dispatches"], r["syncs"],
            r["flops"], r["kernel_bytes"], hw=hw2)["predicted_s"]
            for r in rows]
        after = rank_correlation(after_pred, meas)
        assert before < 1.0          # default constants mis-rank the table
        assert after == pytest.approx(1.0)
        assert after > before

    def test_fit_underdetermined_returns_none(self):
        rows = self._golden_rows()[:2]
        assert fit_offload_constants(rows) is None
        assert fit_offload_constants([]) is None

    def test_joint_fit_separates_roofline_sides(self):
        """The two-level fit recovers hbm_bw AND peak_flops_bf16 from a
        table mixing compute-bound and memory-bound rows — the max() in
        the model is resolved by the intensity-threshold sweep."""
        true = {"pcie_bw": 12e9, "launch_overhead_s": 7e-6,
                "sync_overhead_s": 3e-6, "hbm_bw": 2e11,
                "peak_flops_bf16": 2e12}     # balance: 10 flop/byte
        cases = [                            # (pcie, disp, sync, flops, kb)
            (1e6, 2, 1, 5e9, 1e6), (4e6, 3, 2, 2e10, 4e6),
            (2e6, 1, 1, 8e9, 2e5), (8e6, 4, 2, 1e7, 8e7),
            (1e7, 2, 1, 2e7, 2e8), (5e5, 1, 0, 1e6, 5e7),
            (3e6, 2, 1, 3e10, 6e6), (6e6, 3, 1, 4e7, 1.5e8),
        ]
        rows = []
        for pb, d, s, f, kb in cases:
            t = (pb / true["pcie_bw"] + d * true["launch_overhead_s"]
                 + s * true["sync_overhead_s"]
                 + max(f / true["peak_flops_bf16"], kb / true["hbm_bw"]))
            rows.append({"h2d_bytes": pb, "d2h_bytes": 0.0,
                         "dispatches": d, "syncs": s, "flops": f,
                         "kernel_bytes": kb, "measured_s": t})
        fitted = fit_offload_constants(rows)
        for k, v in true.items():
            assert fitted[k] == pytest.approx(v, rel=1e-6), k

    def test_fit_without_kernel_columns_keeps_defaults(self):
        """A table with no kernel terms (flops = kernel_bytes = 0 on
        every row) drops those columns: hbm_bw / peak keep defaults."""
        true = {"pcie_bw": 8e9, "launch_overhead_s": 5e-5,
                "sync_overhead_s": 1e-5}
        rows = []
        for pb, d, s in [(1e6, 2, 1), (4e6, 3, 2), (2e6, 1, 1),
                         (8e6, 4, 2)]:
            t = (pb / true["pcie_bw"] + d * true["launch_overhead_s"]
                 + s * true["sync_overhead_s"])
            rows.append({"h2d_bytes": pb, "d2h_bytes": 0.0,
                         "dispatches": d, "syncs": s, "flops": 0.0,
                         "kernel_bytes": 0.0, "measured_s": t})
        fitted = fit_offload_constants(rows)
        for k, v in true.items():
            assert fitted[k] == pytest.approx(v, rel=1e-6), k
        assert fitted["hbm_bw"] == HW["hbm_bw"]
        assert fitted["peak_flops_bf16"] == HW["peak_flops_bf16"]

    def test_rank_correlation_basics(self):
        assert rank_correlation([1, 2, 3], [10, 20, 30]) == pytest.approx(1)
        assert rank_correlation([1, 2, 3], [3, 2, 1]) == pytest.approx(-1)
        assert rank_correlation([1, 1, 1], [1, 2, 3]) == 0.0
        assert rank_correlation([1.0], [2.0]) == 0.0
        with pytest.raises(ValueError):
            rank_correlation([1, 2], [1])

    def test_fitted_constants_priced_into_next_program(self, tmp_path):
        """Constants stored for a device class price the NEXT tune call
        on that device (the OpenMP-Advisor loop: measure → fit →
        predict)."""
        tc = TuneCache(tmp_path / "cal")
        be = get_backend("numpy")
        fitted = {"pcie_bw": 123e9, "launch_overhead_s": 7e-5,
                  "sync_overhead_s": 3e-6}
        tc.store_calibration(device_class_key(be), HW, fitted)
        p, _ = build_3mm(n=16)
        pl = tune(p, backend="numpy", reps=1, cache=tc)
        assert pl.meta["tuning"]["hw"]["pcie_bw"] == 123e9
        assert pl.meta["tuning"]["hw"]["launch_overhead_s"] == 7e-5
        # and can be switched off
        pl2 = tune(p, backend="numpy", reps=1, cache=tc,
                   use_calibration=False)
        assert pl2.meta["tuning"]["hw"]["pcie_bw"] == HW["pcie_bw"]

    def test_calibration_shared_per_device_class(self):
        """The carried-over PR 5/6 bug: constants were keyed per BACKEND
        fingerprint, so the same silicon fitted (and read) different
        constants at each stream count / donation flag.  The device-class
        key deliberately drops those knobs — every twin of one device
        reads one store."""
        base = get_backend("numpy")
        twins = [base.variant(n_streams=s) for s in (1, 3, 4)]
        keys = {device_class_key(b) for b in (base, *twins)}
        assert len(keys) == 1
        # while genuinely different devices do not alias
        assert device_class_key(base) != device_class_key(
            get_backend("pinned"))

    def test_calibration_version_keyed(self, tmp_path, monkeypatch):
        tc = TuneCache(tmp_path / "calv")
        dc_key = device_class_key(get_backend("numpy"))
        tc.store_calibration(dc_key, HW, {"pcie_bw": 9e9})
        assert tc.load_calibration(dc_key, HW) == {"pcie_bw": 9e9}
        monkeypatch.setattr(tunecache_mod, "COST_MODEL_VERSION",
                            COST_MODEL_VERSION + 1000)
        assert tc.load_calibration(dc_key, HW) is None

    def test_live_run_records_calibration(self):
        """A measured tune records the fit verdict: row count, both
        correlations, and accepted ⇒ never a correlation regression."""
        p, _ = build("gemm", n=16, iters=4)
        pl = _auto(p)
        cal = pl.tuning_calibration()
        assert cal is not None
        assert cal["n_rows"] >= 3
        assert cal["rank_corr_before"] is not None
        if cal["accepted"]:
            assert cal["rank_corr_after"] >= cal["rank_corr_before"]


class TestRegressionGate:
    """The CI gate must agree with the checked-in baseline — this is the
    same check the workflow step runs, so baseline drift fails here
    first (regenerate: PYTHONPATH=src python
    benchmarks/check_tuning_baseline.py --update)."""

    @pytest.fixture()
    def gate(self):
        bench_dir = str(pathlib.Path(__file__).parent.parent / "benchmarks")
        monkey = bench_dir not in sys.path
        if monkey:
            sys.path.insert(0, bench_dir)
        try:
            import check_tuning_baseline
            yield check_tuning_baseline
        finally:
            if monkey:
                sys.path.remove(bench_dir)

    def test_baseline_matches_current_cost_model(self, gate):
        problems = gate.check()
        assert problems == []

    def test_gate_flags_winner_change(self, gate, monkeypatch, tmp_path):
        golden = json.loads(gate.BASELINE_PATH.read_text())
        golden["programs"]["table2_3mm"]["predicted_winner"] = "bogus/label"
        doctored = tmp_path / "tuning_baseline.json"
        doctored.write_text(json.dumps(golden))
        monkeypatch.setattr(gate, "BASELINE_PATH", doctored)
        problems = gate.check()
        assert any("predicted winner changed" in p for p in problems)
