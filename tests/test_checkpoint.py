"""Checkpoint manager: async save, atomic publish, restore, restart
equivalence, elastic (structure-preserving) restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 8),
                                                        np.float32)),
                   "b": jnp.asarray(rng.standard_normal(8).astype(
                       np.float32))},
        "opt": {"m": {"w": jnp.zeros((8, 8)), "b": jnp.ones(8)},
                "step": jnp.asarray(3, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(10, tree, extra={"data_index": 10}, blocking=True)
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 10 and extra["data_index"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_ordering(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=10)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.all_steps() == [1, 2, 3]


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_restore_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.zeros((8, 8))})


def test_restart_bitwise_equivalence(tmp_path):
    """Train N steps straight vs (train k, crash, resume, finish): params
    must be BITWISE identical — proves checkpoint + data-cursor restore is
    exact (the fault-tolerance core guarantee)."""
    from repro.configs import get_config, reduced
    from repro.launch.train import train
    from repro.runtime import FaultInjector

    cfg = reduced(get_config("internlm2-20b"))
    kw = dict(steps=8, batch=2, seq=16, ckpt_every=4, log_every=100)

    out_a = train(cfg, ckpt_dir=str(tmp_path / "a"), **kw)

    inj = FaultInjector((6,))
    try:
        train(cfg, ckpt_dir=str(tmp_path / "b"), injector=inj, **kw)
        assert False, "injected failure did not fire"
    except RuntimeError:
        pass
    out_b = train(cfg, ckpt_dir=str(tmp_path / "b"), injector=inj, **kw)

    la, lb = jax.tree.leaves(out_a["params"]), jax.tree.leaves(
        out_b["params"])
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints are logical arrays: restore works regardless of the
    device layout at load time (single-device here; the 8-device variant
    runs in test_distributed_subprocess)."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(5, tree, blocking=True)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = jax.tree.map(lambda _: sh, tree)
    restored, _ = mgr.restore(5, tree, shardings)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
