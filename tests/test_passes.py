"""Pass-based planner pipeline (ISSUE 4 tentpole).

Contract under test:

  * ``Pipeline.default(policy)`` reproduces the legacy ``plan()``/
    ``naive_plan()`` behaviour exactly (the refactor is observationally
    neutral),
  * placement policies are registry-pluggable and the grouped policy
    folds every codelet into one group,
  * planning the same program twice yields op-for-op identical plans
    (the compiled-plan fingerprint matches, so cached lowerings stay
    valid), and stream assignment is stable under group *renumbering*
    (appearance order, not group id, decides the stream).
"""
import numpy as np
import pytest

from repro.core import (AdvancedLoad, DelegateStore, GroupDecl, Program,
                        Release, Synchronize, execute, naive_plan, plan,
                        run_host_oracle, transfer_summary)
from repro.core.ir import PlanOp
from repro.core.passes import (NaivePlacement, OptimizedPlacement, Pipeline,
                               PlanDraft, assign_streams, get_placement,
                               placement_names, register_placement)
from repro.optim import plan_step_program
from repro.polybench import build, build_3mm


class TestPipelineParity:
    """The pipeline is the planner: same plans as the public entry."""

    @pytest.mark.parametrize("policy", ["optimized", "naive"])
    def test_pipeline_equals_plan_entry(self, policy):
        p, _ = build_3mm(n=16)
        via_pipeline = Pipeline.default(policy).run(p)
        via_entry = plan(p, policy=policy)
        assert via_pipeline.ops == via_entry.ops
        assert via_pipeline.groups == via_entry.groups

    def test_legacy_optimize_flag_maps_to_policy(self):
        p, _ = build_3mm(n=16)
        assert plan(p, optimize=False).ops == naive_plan(p).ops
        assert plan(p, optimize=True).ops == plan(p, policy="optimized").ops

    def test_pipeline_runs_on_loop_program(self):
        p, _ = build("gemm", n=16, iters=3)
        pl = Pipeline.default("optimized").run(p)
        out, _ = execute(pl, backend="numpy")
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out["out"], oracle["out"], rtol=1e-5)
        assert len(pl.pure_device_loops()) == 1

    def test_draft_var_nbytes(self):
        p, _ = build_3mm(n=8)
        draft = PlanDraft.from_program(p)
        nb = draft.var_nbytes()
        assert nb["A"] == 8 * 8 * 4
        assert set("ABCDEFG") <= set(nb)


class TestPolicyRegistry:
    def test_builtin_policies_registered(self):
        assert {"optimized", "naive", "grouped",
                "pipeline"} <= set(placement_names())
        assert get_placement("optimized") is OptimizedPlacement
        assert get_placement("naive") is NaivePlacement

    def test_unknown_policy_rejected(self):
        p, _ = build_3mm(n=8)
        with pytest.raises(ValueError):
            plan(p, policy="hand-tuned")

    def test_register_custom_policy(self):
        class LatePolicy(NaivePlacement):
            policy = "late"
        register_placement("late", LatePolicy)
        try:
            p, _ = build_3mm(n=8)
            pl = plan(p, policy="late")
            assert pl.meta["policy"] == "late"
            assert pl.ops == naive_plan(p).ops  # same placement rule
        finally:
            from repro.core.passes.placement import _PLACEMENTS
            _PLACEMENTS.pop("late", None)

    def test_grouped_policy_single_group(self):
        """Two kernels with disjoint data → two groups under the default
        union-find, ONE under the grouped policy."""
        p = Program("two_islands")
        p.bind("a", np.arange(8, dtype=np.float32))
        p.bind("b", np.arange(8, dtype=np.float32) + 10.0)
        p.offload(lambda xp, a: {"x": a * 2.0}, reads=("a",),
                  writes=("x",), name="k0")
        p.offload(lambda xp, b: {"y": b + 1.0}, reads=("b",),
                  writes=("y",), name="k1")
        p.host(lambda xp, x, y: {"o": x + y}, reads=("x", "y"),
               writes=("o",), name="c")
        p.set_outputs("o")
        default = plan(p)
        grouped = plan(p, policy="grouped")
        assert len(default.groups) == 2
        assert len(grouped.groups) == 1
        assert len(grouped.directives(GroupDecl)) == 1
        assert len(grouped.directives(Release)) == 1
        # same results, same transfer counts as the optimized policy
        out_d, s_d = execute(default, backend="numpy")
        out_g, s_g = execute(grouped, backend="numpy")
        for k in p.outputs:
            np.testing.assert_array_equal(out_d[k], out_g[k])
        assert s_d.transfer_counts()["h2d_transfers"] == \
            s_g.transfer_counts()["h2d_transfers"]

    def test_pipeline_policy_one_group_per_stage(self):
        """ISSUE 9: the GPipe-derived policy puts every codelet in its
        own group — 3mm's three matmuls become three stages with three
        releases — and still computes the same answer."""
        p, _ = build_3mm(n=16)
        pipe = plan(p, policy="pipeline")
        assert pipe.meta["policy"] == "pipeline"
        n_stages = len(list(p.offload_blocks()))
        assert len(pipe.groups) == n_stages == 3
        assert all(len(blks) == 1 for blks in pipe.groups.values())
        assert len(pipe.directives(GroupDecl)) == n_stages
        assert len(pipe.directives(Release)) == n_stages
        out, _ = execute(pipe, backend="numpy")
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out["out"], oracle["out"], rtol=1e-5)


class TestDeterminism:
    """ISSUE 4 satellite: stream ids stable under group renumbering."""

    @pytest.mark.parametrize("builder,kw", [
        ("3mm", dict(n=16)), ("bicg", dict(n=16)),
        ("gemm", dict(n=16, iters=3))])
    def test_two_plans_of_same_program_identical(self, builder, kw):
        """Planning twice must give op-for-op equal plans — the executor
        fingerprints compiled lowerings with hash(tuple(plan.ops)), so
        any drift (e.g. stream ids depending on dict order) silently
        recompiles every cached jit."""
        p, _ = build(builder, **kw)
        pl1, pl2 = plan(p), plan(p)
        assert pl1.ops == pl2.ops
        assert hash(tuple(pl1.ops)) == hash(tuple(pl2.ops))

    def test_train_step_plans_identical(self):
        p = plan_step_program(n_steps=3)
        assert plan(p).ops == plan(p).ops

    def test_streams_follow_appearance_order_not_group_id(self):
        """The same directive sequence with renumbered group ids must get
        the same stream sequence: appearance order decides."""
        def seq(groups):
            return [PlanOp("directive", directive=AdvancedLoad(
                var=f"v{i}", group=g)) for i, g in enumerate(groups)]
        low = assign_streams(seq([0, 1, 0, 1]), n_streams=2)
        high = assign_streams(seq([7, 3, 7, 3]), n_streams=2)  # renumbered
        assert [op.directive.stream for op in low] == \
            [op.directive.stream for op in high] == [1, 2, 1, 2]

    def test_stream_count_parameter(self):
        ops = [PlanOp("directive", directive=DelegateStore(var=f"v{g}",
                                                           group=g))
               for g in (0, 1, 2, 3)]
        one = assign_streams(ops, n_streams=1)
        assert {op.directive.stream for op in one} == {1}
        four = assign_streams(ops, n_streams=4)
        assert [op.directive.stream for op in four] == [1, 2, 3, 4]

    def test_sync_shares_its_groups_stream(self):
        p, _ = build("bicg", n=16)
        pl = plan(p, n_streams=4)
        by_group = {}
        for d in pl.directives():
            if isinstance(d, (AdvancedLoad, DelegateStore, Synchronize)):
                by_group.setdefault(d.group, set()).add(d.stream)
        for streams in by_group.values():
            assert len(streams) == 1


class TestPassIndependence:
    def test_noupdate_and_group_passes_idempotent(self):
        from repro.core.passes import (GroupFinalizePass, LinearizePass,
                                       NoupdatePass)
        p, _ = build_3mm(n=8)
        draft = PlanDraft.from_program(p)
        pipeline = Pipeline.default("optimized")
        for pas in pipeline.passes:
            pas.run(draft)
        before = list(draft.ops)
        for pas in (LinearizePass(), NoupdatePass(), GroupFinalizePass()):
            pas.run(draft)
        assert draft.ops == before

    def test_transfer_summary_unchanged_by_refactor(self):
        """The seed's worked example still produces the paper's Table 2
        schedule: 4 loads / 1 store / noupdate on E and F."""
        p, _ = build_3mm(n=32)
        s = transfer_summary(plan(p))
        assert s["loads"] == 4 and s["stores"] == 1
        assert s["noupdate_args"] == 2
