"""Optimizer host-offload: the paper technique at training scale."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (emit, execute, naive_plan, plan)
from repro.optim import (adamw, host_memory_kind, offload_shardings,
                         plan_step_program, supports_pinned_host)


def test_train_loop_program_schedule():
    """The miniature train-loop program: the planner uploads the batch once
    (hoisted), keeps weights/optimizer state resident across loop
    iterations (noupdate), and fetches the loss once at the end."""
    p = plan_step_program(n_steps=4)
    pl = plan(p)
    _, s_opt = execute(pl)
    _, s_nv = execute(naive_plan(p))
    # optimized: w, opt_m, batch uploaded once each; naive re-uploads per
    # kernel per iteration
    assert s_opt.h2d_transfers == 3
    assert s_nv.h2d_transfers > 3 * 4
    assert s_opt.d2h_transfers <= 2          # final loss (+ w output)
    text = emit(pl)
    assert "noupdate=true" in text


def test_train_loop_results_match_oracle():
    from repro.core import run_host_oracle
    p = plan_step_program(n_steps=3)
    out, _ = execute(plan(p))
    oracle = run_host_oracle(p)
    np.testing.assert_allclose(out["w"], oracle["w"], rtol=1e-5)
    np.testing.assert_allclose(out["final_loss"], oracle["final_loss"],
                               rtol=1e-5)


def test_offload_shardings_memory_kind():
    """Platforms with a pinned_host space get host-kind shardings; CPU
    jaxlib (single memory space) degrades to the identity transform."""
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    tree = {"m": sh, "v": {"x": sh}}
    off = offload_shardings(tree)
    if supports_pinned_host():
        assert off["m"].memory_kind == "pinned_host"
        assert off["v"]["x"].memory_kind == "pinned_host"
    else:
        assert host_memory_kind() is None
        assert off["m"] is sh and off["v"]["x"] is sh


def test_offloaded_optimizer_step_compiles_and_runs():
    """jit with pinned_host optimizer-state shardings: the offloaded
    optimizer streams state in/out (advancedload/delegatestore) and the
    numerics match the on-device optimizer exactly."""
    from repro.optim import offloaded_optimizer

    base = adamw(lr=1e-2)
    opt = offloaded_optimizer(base)
    params = {"w": jnp.ones((32, 32), jnp.float32)}
    state = base.init(params)
    grads = {"w": jnp.full((32, 32), 0.5, jnp.float32)}

    if supports_pinned_host():
        dev = jax.devices()[0]
        d_sh = jax.sharding.SingleDeviceSharding(dev)
        h_sh = d_sh.with_memory_kind("pinned_host")
        host_state = jax.tree.map(
            lambda x: jax.device_put(x, h_sh) if hasattr(x, "shape") and
            x.ndim > 0 else x, state)

        state_sh = jax.tree.map(
            lambda x: h_sh if hasattr(x, "ndim") and x.ndim > 0 else d_sh,
            state)
        f = jax.jit(lambda p, s, g: opt.update(g, s, p),
                    in_shardings=(d_sh, state_sh, d_sh),
                    out_shardings=(d_sh, state_sh))
        # the CPU backend cannot LOAD placement-annotation custom calls, so
        # the criterion here is lowering with the host-memory annotations
        # present (real compile+run happens on TPU; the pinned_host
        # transfers themselves are exercised by the DeviceResidency path)
        lowered = f.lower(params, host_state, grads)
        hlo = lowered.as_text()
        assert "pinned_host" in hlo or "annotate_device_placement" in hlo
    else:
        # single-memory-space platform: the offloaded update must still
        # compile and run (identity placement), proving the fallback works
        new_p_off, _ = jax.jit(lambda p, s, g: opt.update(g, s, p))(
            params, state, grads)
        assert np.isfinite(np.asarray(new_p_off["w"])).all()

    # numerics of the offloaded update == base update (plain placement)
    new_p, _ = jax.jit(lambda p, s, g: base.update(g, s, p))(params, state,
                                                             grads)
    ref_p, _ = base.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(ref_p["w"]), rtol=1e-6)
