"""Plan-space explorer + roofline-backed cost model (ISSUE 4).

Acceptance criteria under test:

  * ``plan(program, policy="auto")`` enumerates ≥ 8 candidate plans on
    the 3mm example, every candidate passes the simulate-and-fix pass,
  * the chosen plan's measured wall time is ≤ the fixed "optimized"
    plan's on both the numpy and jax backends (within the recorded
    table — both were measured by the same procedure),
  * ``plan.meta["tuning"]`` records predicted AND measured cost for each
    candidate,
  * predicted transfer bytes match ``transfer_summary()`` directive
    counts × loop trip multipliers × dtype sizes (golden file), and the
    executed ``ExecStats`` bytes,
  * a placement policy the simulator rejects is recorded invalid and
    never ranked.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import (PlanConfig, Program, execute, plan, predict_cost,
                        transfer_summary, tune)
from repro.core.passes import NaivePlacement, register_placement
from repro.core.passes.placement import _PLACEMENTS
from repro.optim import plan_step_program
from repro.polybench import build_3mm

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "cost_model.json")
    .read_text())

FIXED_OPTIMIZED = "optimized/streams2/fuse/nodonate"


def _tuned_3mm(backend):
    p, _ = build_3mm(n=32)
    return plan(p, policy="auto", backend=backend, reps=2)


def _rec_for(tuning, label):
    """The candidate record carrying ``label`` (possibly as an alias —
    identical plans are deduplicated)."""
    for c in tuning["candidates"]:
        if c["label"] == label or label in c.get("aliases", ()):
            return c
    raise KeyError(label)


class TestAcceptance:
    @pytest.mark.parametrize("backend", ["numpy", "jax"])
    def test_auto_policy_on_3mm(self, backend):
        pl = _tuned_3mm(backend)
        tuning = pl.meta["tuning"]
        valid = [c for c in tuning["candidates"] if c["valid"]]
        # ≥ 8 candidates, every one simulator-approved
        assert len(valid) >= 8
        assert all(c["error"] is None for c in valid)
        # predicted AND measured recorded for each candidate
        for c in valid:
            assert c["predicted_s"] > 0.0
            assert c["measured_s"] is not None and c["measured_s"] > 0.0
            assert c["rank"] is not None
        # dominance pruning (ISSUE 5): _measure ran exactly once per
        # distinct execution class — merged configs inherit their class
        # survivor's number instead of re-measuring it
        survivors = [c for c in valid if c["alias_of"] is None]
        assert pl.meta["tuning_cache"]["measurements"] == len(survivors)
        assert len(survivors) < len(valid)
        for c in valid:
            if c["alias_of"] is not None:
                surv = next(s for s in survivors
                            if s["label"] == c["alias_of"])
                assert c["measured_s"] == surv["measured_s"]
                assert c["predicted_s"] == surv["predicted_s"]
        # chosen is the measured argmin → ≤ the fixed optimized plan
        chosen = _rec_for(tuning, tuning["chosen"])
        fixed = _rec_for(tuning, FIXED_OPTIMIZED)
        assert chosen["measured_s"] <= fixed["measured_s"]
        # ranks follow predicted cost
        ranked = sorted(valid, key=lambda c: c["rank"])
        assert all(a["predicted_s"] <= b["predicted_s"]
                   for a, b in zip(ranked, ranked[1:]))

    def test_winner_executes_correctly(self):
        p, _ = build_3mm(n=32)
        pl = plan(p, policy="auto", backend="numpy", reps=1)
        from repro.core import run_host_oracle
        out, _ = execute(pl, backend="numpy",
                         fuse_loops=pl.meta["fuse_loops"], mode="compiled")
        oracle = run_host_oracle(p)
        np.testing.assert_allclose(out["out"], oracle["out"], rtol=2e-3,
                                   atol=1e-3)

    def test_optimized_predicted_cheaper_than_naive(self):
        """The cost model must reproduce the paper's §3 ordering on the
        worked example: fewer/hoisted transfers → lower predicted cost."""
        pl = _tuned_3mm("numpy")
        tuning = pl.meta["tuning"]
        opt = _rec_for(tuning, FIXED_OPTIMIZED)
        nv = _rec_for(tuning, "naive/streams2/fuse/nodonate")
        assert opt["predicted_s"] < nv["predicted_s"]
        assert opt["h2d_bytes"] < nv["h2d_bytes"]
        assert opt["d2h_bytes"] < nv["d2h_bytes"]

    def test_emitter_prints_tuning_verdict(self):
        from repro.core import emit
        pl = _tuned_3mm("numpy")
        text = emit(pl)
        assert "tuned, variant=" in text
        assert "predicted=" in text
        assert "measured=" in text


class TestCostModelGolden:
    """Predicted transfer schedule == golden == transfer_summary ×
    multipliers × dtype sizes == executed bytes."""

    @pytest.mark.parametrize("prog_key,builder", [
        ("3mm_n32", lambda: build_3mm(n=32)[0]),
        ("train_step_n4", lambda: plan_step_program(n_steps=4)),
    ])
    @pytest.mark.parametrize("policy", ["optimized", "naive"])
    def test_predicted_matches_golden_and_execution(self, prog_key,
                                                    builder, policy):
        p = builder()
        pl = plan(p, policy=policy)
        pred = predict_cost(pl, PlanConfig(policy=policy))
        golden = GOLDEN[prog_key][policy]
        for k, v in golden.items():
            assert pred[k] == v, f"{prog_key}/{policy}/{k}"
        _, stats = execute(pl, backend="numpy")
        assert pred["h2d_bytes"] == stats.h2d_bytes
        assert pred["d2h_bytes"] == stats.d2h_bytes
        assert pred["loads"] == stats.h2d_transfers
        assert pred["stores"] == stats.d2h_transfers
        assert pred["syncs"] == stats.syncs

    def test_loop_free_counts_equal_summary_times_sizes(self):
        """On a loop-free program every directive fires once, so the
        prediction is literally transfer_summary() × per-var nbytes."""
        p, _ = build_3mm(n=32)
        pl = plan(p)
        pred = predict_cost(pl, PlanConfig())
        s = transfer_summary(pl)
        nb = pl.meta["var_nbytes"]
        assert pred["loads"] == s["loads"]
        assert pred["stores"] == s["stores"]
        assert pred["h2d_bytes"] == s["loads"] * nb["A"]   # all n×n f32
        assert pred["d2h_bytes"] == s["stores"] * nb["G"]

    def test_fused_loop_costs_one_dispatch(self):
        """Whole-loop lowering shows up in the dispatch term: the same
        plan priced with fuse on/off differs exactly by the amortized
        per-iteration launches."""
        from repro.polybench import build
        p, _ = build("gemm", n=16, iters=4)
        pl = plan(p)
        fused = predict_cost(pl, PlanConfig(fuse_loops=True))
        unfused = predict_cost(pl, PlanConfig(fuse_loops=False))
        assert fused["kernel_launches"] == unfused["kernel_launches"] == 4
        assert fused["dispatches"] < unfused["dispatches"]
        assert fused["predicted_s"] < unfused["predicted_s"]

    def test_fused_nest_inside_impure_loop_relaunches(self):
        """A pure inner loop under an impure outer loop re-launches per
        outer iteration: the dispatch term must scale with the OUTER
        trip count, matching the compiled executor's fused_launches."""
        p = Program("half_pure")
        p.bind("A", np.ones((8, 8), np.float32))
        p.bind("C", np.ones((8, 8), np.float32))
        p.bind("h", np.ones((2,), np.float32))
        with p.loop(3):
            p.host(lambda xp, h: {"h": h * 1.5}, reads=("h",),
                   writes=("h",), name="hostwork")
            with p.loop(4):
                p.offload(lambda xp, A, C: {"C": 0.5 * (A @ C)},
                          reads=("A", "C"), writes=("C",), name="k")
        p.host(lambda xp, C, h: {"out": C[:1] + h[:1]},
               reads=("C", "h"), writes=("out",), name="consume")
        p.set_outputs("out")
        pl = plan(p)
        _, stats = execute(pl, mode="compiled", backend="numpy")
        pred = predict_cost(pl, PlanConfig(fuse_loops=True))
        # 3 fused inner-loop launches; transfers add theirs on top
        assert stats.fused_launches == 3
        assert pred["dispatches"] == 3 + pred["loads"] + pred["stores"]

    def test_pure_but_unfusable_nest_priced_per_iteration(self):
        """A pure outer loop whose body mixes a block WITH an inner loop
        never fuses whole (the compiler needs exactly one child node):
        the dispatch term must match the executor's per-outer-iteration
        launches, not price the nest as one dispatch."""
        p = Program("mixed_nest")
        p.bind("A", np.ones((8, 8), np.float32))
        p.bind("C", np.ones((8, 8), np.float32))
        with p.loop(3):
            p.offload(lambda xp, A, C: {"C": C + 0.1 * A},
                      reads=("A", "C"), writes=("C",), name="pre")
            with p.loop(4):
                p.offload(lambda xp, A, C: {"C": 0.5 * (A @ C)},
                          reads=("A", "C"), writes=("C",), name="k")
        p.host(lambda xp, C: {"out": C[:1]}, reads=("C",),
               writes=("out",), name="consume")
        p.set_outputs("out")
        pl = plan(p)
        assert set(pl.pure_device_loops()) == {0, 1}   # both pure...
        _, stats = execute(pl, mode="compiled", backend="numpy")
        assert stats.fused_launches == 6   # ...but only the inner fuses:
        # 3 × (1 segment launch + 1 inner-loop launch)
        pred = predict_cost(pl, PlanConfig(fuse_loops=True))
        assert pred["dispatches"] == 6 + pred["loads"] + pred["stores"]

    def test_flops_term_from_hlo(self):
        """The kernel term reuses the roofline HLO machinery: the 3mm
        chain of three n×n matmuls prices ≈ 3 × 2n³ FLOPs."""
        from repro.core.analysis import analyze
        from repro.core.tuner import _block_flops
        p, _ = build_3mm(n=32)
        pl = plan(p)
        flops = _block_flops(p, analyze(p).shapes)
        pred = predict_cost(pl, PlanConfig(), flops)
        assert pred["flops"] == pytest.approx(3 * 2 * 32 ** 3, rel=0.2)


class TestInvalidCandidates:
    def test_rejected_policy_never_ranked(self):
        """A placement policy whose plan the simulator rejects is
        recorded with valid=False and excluded from ranking/measuring —
        policy=auto never returns or ranks a broken plan."""
        class EagerStore(NaivePlacement):
            """Downloads a program input before anything ran on the
            device — a gap the simulator cannot fix (no valid device
            copy exists for the store) → rejected, not repaired."""
            policy = "eager-store"

            def place(self, draft):
                from repro.core import DelegateStore
                from repro.core.ir import PlanOp
                from repro.core.passes.linearize import Insertion
                ins = super().place(draft)
                first_input = sorted(draft.program.inputs)[0]
                return [Insertion(0, -1, PlanOp(
                    "directive",
                    directive=DelegateStore(var=first_input, group=0)))
                ] + ins

        register_placement("eager-store", EagerStore)
        try:
            p, _ = build_3mm(n=16)
            pl = tune(p, backend="numpy",
                      policies=("optimized", "eager-store"),
                      streams=(1, 2), reps=1)
            tuning = pl.meta["tuning"]
            bad = [c for c in tuning["candidates"]
                   if c["config"]["policy"] == "eager-store"]
            assert bad and all(not c["valid"] for c in bad)
            assert all("invalid plan" in c["error"] for c in bad)
            assert all(c["rank"] is None and c["measured_s"] is None
                       for c in bad)
            assert tuning["chosen"].startswith("optimized")
        finally:
            _PLACEMENTS.pop("eager-store", None)

    def test_all_invalid_raises(self):
        class Broken(NaivePlacement):
            policy = "broken"

            def place(self, draft):
                from repro.core import DelegateStore
                from repro.core.ir import PlanOp
                from repro.core.passes.linearize import Insertion
                first_input = sorted(draft.program.inputs)[0]
                return [Insertion(0, -1, PlanOp(
                    "directive",
                    directive=DelegateStore(var=first_input, group=0)))
                ] + super().place(draft)

        register_placement("broken", Broken)
        try:
            p, _ = build_3mm(n=8)
            with pytest.raises(RuntimeError):
                tune(p, backend="numpy", policies=("broken",), reps=1)
        finally:
            _PLACEMENTS.pop("broken", None)


class TestTunerKnobs:
    def test_top_k_limits_measurement(self):
        """top_k bounds the number of MEASURED execution classes (merged
        configs still inherit the class result)."""
        p, _ = build_3mm(n=16)
        pl = tune(p, backend="numpy", top_k=1, reps=1)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        assert pl.meta["tuning_cache"]["measurements"] == 1
        measured = [c for c in valid if c["measured_s"] is not None
                    and c["alias_of"] is None]
        assert len(measured) == 1 and measured[0]["rank"] == 1
        # the other class (naive placement) was skipped entirely
        assert any(c["measured_s"] is None for c in valid)
        assert pl.meta["tuning"]["chosen"] == measured[0]["label"]

    def test_measure_off_ranks_by_prediction(self):
        p, _ = build_3mm(n=16)
        pl = tune(p, backend="numpy", measure=False)
        tuning = pl.meta["tuning"]
        valid = [c for c in tuning["candidates"] if c["valid"]]
        assert all(c["measured_s"] is None for c in valid)
        assert tuning["chosen"] == min(
            valid, key=lambda c: c["predicted_s"])["label"]

    def test_abstract_inputs_skip_measurement(self):
        import jax
        p = Program("abstract")
        p.bind("A", jax.ShapeDtypeStruct((8, 8), np.float32))
        p.offload(lambda xp, A: {"B": A * 2.0}, reads=("A",),
                  writes=("B",), name="k")
        p.host(lambda xp, B: {"o": B}, reads=("B",), writes=("o",),
               name="c")
        p.set_outputs("o")
        pl = tune(p, backend="numpy")
        assert all(c["measured_s"] is None
                   for c in pl.meta["tuning"]["candidates"])

    def test_plan_rejects_tuner_kwargs_for_fixed_policies(self):
        p, _ = build_3mm(n=8)
        with pytest.raises(TypeError):
            plan(p, top_k=2)                 # tuner knob, fixed policy
        with pytest.raises(TypeError):
            plan(p, policy="naive", reps=3)
        with pytest.raises(TypeError):
            plan(p, backend="numpy")         # backend is auto-only too

    def test_execute_follows_winner_fuse_flag(self):
        """execute() defaults fuse_loops from the plan's meta, so a
        tuned nofuse winner runs the variant the tuner measured without
        the winner_exec_kwargs side-channel."""
        from repro.polybench import build
        p, _ = build("gemm", n=16, iters=5)
        pl = plan(p)
        pl.meta["fuse_loops"] = False
        _, s = execute(pl, mode="compiled", backend="numpy")
        assert s.fused_launches == 5          # per-iteration path
        _, s2 = execute(pl, mode="compiled", backend="numpy",
                        fuse_loops=True)      # explicit arg still wins
        assert s2.fused_launches == 1

    def test_plan_auto_pins_stream_axis_from_n_streams(self):
        p, _ = build_3mm(n=8)
        pl = plan(p, policy="auto", backend="numpy", n_streams=1,
                  measure=False)
        for c in pl.meta["tuning"]["candidates"]:
            assert c["config"]["n_streams"] == 1

    def test_nodonate_candidates_never_measured_with_donation(self):
        """A donate=True backend handed to tune() must not leak donation
        into nodonate candidates (and vice versa): _measure swaps to the
        matching twin in both directions."""
        from repro.core import JaxDeviceBackend
        from repro.core.tuner import _donation_variant
        be = JaxDeviceBackend(donate=True)
        off = _donation_variant(be, False)
        assert isinstance(off, JaxDeviceBackend) and not off.donate
        assert _donation_variant(off, True).donate
        assert _donation_variant(be, True) is be
        assert _donation_variant(off, False) is off

    def test_winner_exec_kwargs_honor_variant(self):
        from repro.core import JaxDeviceBackend, winner_exec_kwargs
        p, _ = build_3mm(n=16)
        pl = plan(p)
        pl.meta.update(fuse_loops=False, donate=True)
        kw = winner_exec_kwargs(pl, "jax")
        assert kw["fuse_loops"] is False
        assert isinstance(kw["backend"], JaxDeviceBackend)
        assert kw["backend"].donate
        pl.meta["donate"] = False
        assert not winner_exec_kwargs(pl, "jax")["backend"].donate
        out, _ = execute(pl, **winner_exec_kwargs(pl, "numpy"))
        assert set(out) == set(p.outputs)

    def test_explicit_config_list(self):
        p, _ = build_3mm(n=16)
        cfgs = [PlanConfig(policy="optimized", n_streams=1),
                PlanConfig(policy="naive", n_streams=1)]
        pl = tune(p, backend="numpy", configs=cfgs, reps=1)
        assert len(pl.meta["tuning"]["candidates"]) == 2


class TestKernelAxis:
    """ISSUE 6: the tuner's kernel tile/block axis.  The flash-attention
    step program carries a kernel-tagged block, so the candidate grid
    grows a per-kernel variant choice, priced by the two-level roofline
    and re-executed through ``winner_exec_kwargs``."""

    GRID = dict(policies=("optimized",), streams=(1,), fuse=(True,),
                donate=(False,))

    def _tuned(self, **kw):
        from repro.optim import attention_step_program
        p = attention_step_program(n_steps=1)
        kw = dict(self.GRID, reps=1, **kw)
        return p, plan(p, policy="auto", **kw)

    def test_enumerates_at_least_three_variants(self):
        p, pl = self._tuned(measure=False)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        kvs = {json.dumps(c["config"]["kernel_variants"]) for c in valid}
        assert len(kvs) >= 3
        # every candidate's label names the tile it launches
        assert all("flash_attention[" in c["label"] for c in valid)

    def test_kernel_s_differs_across_tile_candidates(self):
        """The tentpole property: kernel_s is no longer plan-invariant —
        smaller q tiles re-read K/V more, so HBM bytes (and kernel_s)
        differ across candidates of the same placement."""
        p, pl = self._tuned(measure=False)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        by_bq = {}
        for c in valid:
            bq = dict(dict(c["config"]["kernel_variants"])
                      ["flash_attention"])["block_q"]
            by_bq.setdefault(bq, c)
        assert set(by_bq) == {64, 128}
        assert by_bq[64]["kernel_bytes"] > by_bq[128]["kernel_bytes"]
        assert by_bq[64]["kernel_s"] != by_bq[128]["kernel_s"]
        assert by_bq[64]["flops"] == by_bq[128]["flops"]

    def test_winner_variant_recorded_and_reexecuted(self):
        from repro.core import winner_exec_kwargs
        p, pl = self._tuned()
        t = pl.meta["tuning"]
        kv = t["kernel_variants"]
        assert set(kv) == {"flash_attention"}
        assert set(kv["flash_attention"]) == {"block_q", "block_k"}
        assert pl.meta["kernel_variants"] == kv
        assert kv["flash_attention"]["block_q"] in (64, 128)
        # the chosen label names exactly the recorded variant
        assert f"block_q={kv['flash_attention']['block_q']}" \
            in t["chosen"]
        kw = winner_exec_kwargs(pl)
        assert kw["kernel_variants"] == kv
        out, _ = execute(pl, dict(p.inputs), **kw)
        # numerics are tile-invariant: another variant agrees
        other = {"flash_attention": {"block_q": 64, "block_k": 64}}
        out2, _ = execute(pl, dict(p.inputs), mode="compiled",
                          kernel_variants=other, backend=kw["backend"])
        np.testing.assert_allclose(np.asarray(out["final_loss"]),
                                   np.asarray(out2["final_loss"]),
                                   rtol=1e-5)

    def test_dominance_pruning_keys_on_variant(self):
        """Distinct tiles are distinct execution classes (measured
        separately); identical launches merge."""
        p, pl = self._tuned()
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        survivors = [c for c in valid if c["alias_of"] is None]
        kvs = {json.dumps(c["config"]["kernel_variants"])
               for c in survivors}
        assert len(kvs) == len(survivors)
        assert all(c["measured_s"] is not None for c in survivors)

    def test_kernel_free_grid_and_labels_unchanged(self):
        """Programs without kernel-tagged blocks keep the plain policy
        grid (4 policies x 2 streams x 2 fuse x 2 donate since the
        pipeline policy landed): no kernel suffix in any label, empty
        variant maps."""
        p, _ = build_3mm(n=16)
        pl = plan(p, policy="auto", backend="numpy", measure=False)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        assert len(valid) == 64
        assert all("[" not in c["label"] for c in valid)
        assert pl.meta["tuning"]["kernel_variants"] == {}

    def test_cache_roundtrip_restores_variant(self, tmp_path):
        from repro.core import TuneCache
        from repro.optim import attention_step_program
        tc = TuneCache(tmp_path / "kv")
        p1 = attention_step_program(n_steps=1)
        pl1 = tune(p1, reps=1, cache=tc, **self.GRID)
        p2 = attention_step_program(n_steps=1)
        pl2 = tune(p2, reps=1, cache=tc, **self.GRID)
        assert pl2.meta["tuning_cache"]["hit"] is True
        assert pl2.meta["tuning"] == pl1.meta["tuning"]
        assert pl2.meta["kernel_variants"] == pl1.meta["kernel_variants"]
        assert pl2.meta["kernel_variants"]
