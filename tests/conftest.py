import pytest


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path, monkeypatch):
    """Point the persistent tuning cache (repro.core.tunecache) at a
    per-test directory: tests must not hit tables measured by earlier
    tests or earlier pytest runs (a stale hit would, e.g., make a
    measurement-count assertion see zero measurements).  Within one
    test, repeated tune() calls still share the cache — which is how
    the cache-hit tests exercise it."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tunecache"))
