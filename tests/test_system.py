"""End-to-end system tests: training learns, serving generates, the
Polybench suite (the paper's workloads) is exact and transfer-optimal."""
import numpy as np
import pytest

from repro.core import execute, naive_plan, plan, run_host_oracle
from repro.polybench import PROBLEMS, build


SMALL = {
    "2mm": dict(n=48), "3mm": dict(n=48), "gemm": dict(n=48, iters=3),
    "atax": dict(n=64), "bicg": dict(n=64), "mvt": dict(n=64),
    "gesummv": dict(n=64), "syrk": dict(n=48, iters=2),
    "covariance": dict(n=48), "jacobi2d": dict(n=32, iters=4),
}


@pytest.mark.parametrize("name", sorted(PROBLEMS), ids=str)
def test_polybench_correct_and_transfer_optimal(name):
    p, _ = build(name, **SMALL[name])
    oracle = run_host_oracle(p)
    out_opt, s_opt = execute(plan(p))
    out_nv, s_nv = execute(naive_plan(p))
    for k in p.outputs:
        np.testing.assert_allclose(out_opt[k], oracle[k], rtol=2e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(out_nv[k], oracle[k], rtol=2e-3,
                                   atol=1e-3)
    assert s_opt.h2d_transfers <= s_nv.h2d_transfers
    assert s_opt.d2h_transfers <= s_nv.d2h_transfers
    assert s_opt.h2d_bytes + s_opt.d2h_bytes <= \
        s_nv.h2d_bytes + s_nv.d2h_bytes


def test_gemm_loop_residency_win():
    """The iterated-GEMM case: optimized plan keeps A/B/C resident across
    the loop (2 + 1 loads total vs 3 per iteration)."""
    p, _ = build("gemm", n=48, iters=4)
    _, s_opt = execute(plan(p))
    _, s_nv = execute(naive_plan(p))
    assert s_opt.h2d_transfers == 3
    assert s_nv.h2d_transfers == 12


def test_train_loss_decreases():
    """~100M-scale behaviour at smoke scale: CE on the learnable synthetic
    stream drops by > 0.2 nats over 80 steps."""
    from repro.configs import get_config, reduced
    from repro.launch.train import train
    import tempfile

    cfg = reduced(get_config("internlm2-20b"))
    with tempfile.TemporaryDirectory() as d:
        out = train(cfg, steps=120, batch=8, seq=64, ckpt_dir=d,
                    ckpt_every=1000, log_every=10)
    losses = [v for _, v in out["losses"]]
    # compare best-of-late vs first log to be robust to step noise
    assert min(losses[-4:]) < losses[0] - 0.15, losses


def test_serve_generates_tokens():
    from repro.configs import get_config, reduced
    from repro.launch.serve import serve

    cfg = reduced(get_config("rwkv6-3b"))
    out = serve(cfg, batch=3, prompt_len=8, gen=6)
    gen = out["generated"]
    assert gen.shape == (3, 6)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()


def test_serve_deterministic():
    from repro.configs import get_config, reduced
    from repro.launch.serve import serve

    cfg = reduced(get_config("internlm2-20b"))
    a = serve(cfg, batch=2, prompt_len=8, gen=4, seed=5)["generated"]
    b = serve(cfg, batch=2, prompt_len=8, gen=4, seed=5)["generated"]
    np.testing.assert_array_equal(a, b)
