"""Sharding-rule unit tests (no forced device count needed: rules are pure
functions of a mesh we can build abstractly via jax.sharding.Mesh over the
single CPU device is impossible — so we use AbstractMesh)."""
import numpy as np
from jax.sharding import PartitionSpec

from repro.configs import get_config
from repro.distributed.sharding import (abstract_mesh, batch_axes, make_rules,
                                        spec_for_axes)


def _mesh(shape=(16, 16), axes=("data", "model")):
    return abstract_mesh(shape, axes)


def test_divisibility_guard_drops_heads():
    """qwen2.5's 40 q-heads can't shard on a 16-way model axis."""
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (5120, 40, 128),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec == PartitionSpec("data", None, None)
    assert any(d[1] == "heads" for d in rules.dropped)


def test_divisible_heads_shard():
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (6144, 48, 128),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec == PartitionSpec("data", "model", None)


def test_experts_shard_on_model():
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (128, 2048, 768),
                         ("experts", "embed", "ffn"), "w_up")
    # experts take model; ffn would also want model but it's used
    assert spec == PartitionSpec("model", "data", None)


def test_axis_used_only_once():
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (16384, 6144), ("ffn", "embed"), "w_down")
    assert spec == PartitionSpec("model", "data")
    spec2 = spec_for_axes(rules, (16384, 16384), ("ffn", "vocab"), "x")
    assert spec2 == PartitionSpec("model", None)  # vocab→model already used


def test_fsdp_layers_mode_prefers_layer_dim():
    rules = make_rules(_mesh(), "train", fsdp_layers=True)
    spec = spec_for_axes(rules, (48, 6144, 16384),
                         ("layers", "embed", "ffn"), "stacked")
    assert spec == PartitionSpec("data", None, "model")


def test_serve_rules_no_fsdp():
    rules = make_rules(_mesh(), "decode")
    spec = spec_for_axes(rules, (6144, 48, 128),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec == PartitionSpec(None, "model", None)


def test_batch_axes_multipod():
    assert batch_axes(_mesh((2, 16, 16), ("pod", "data", "model"))) == \
        ("pod", "data")
    assert batch_axes(_mesh()) == ("data",)


def test_long500k_batch1_replicates():
    from repro.distributed.sharding import batch_specs
    import jax
    rules = make_rules(_mesh(), "decode")
    cfg = get_config("rwkv6-3b")
    specs = batch_specs(rules, cfg, "decode",
                        {"tokens": jax.ShapeDtypeStruct((1,), np.int32)})
    assert specs["tokens"].spec == PartitionSpec(None)
