"""Sharding-rule unit tests (no forced device count needed: rules are pure
functions of a mesh we can build abstractly via jax.sharding.Mesh over the
single CPU device is impossible — so we use AbstractMesh)."""
import numpy as np
from jax.sharding import PartitionSpec

from repro.configs import get_config
from repro.distributed.sharding import (abstract_mesh, batch_axes, make_rules,
                                        spec_for_axes)


def _mesh(shape=(16, 16), axes=("data", "model")):
    return abstract_mesh(shape, axes)


def test_divisibility_guard_drops_heads():
    """qwen2.5's 40 q-heads can't shard on a 16-way model axis."""
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (5120, 40, 128),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec == PartitionSpec("data", None, None)
    assert any(d[1] == "heads" for d in rules.dropped)


def test_divisible_heads_shard():
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (6144, 48, 128),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec == PartitionSpec("data", "model", None)


def test_experts_shard_on_model():
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (128, 2048, 768),
                         ("experts", "embed", "ffn"), "w_up")
    # experts take model; ffn would also want model but it's used
    assert spec == PartitionSpec("model", "data", None)


def test_axis_used_only_once():
    rules = make_rules(_mesh(), "train")
    spec = spec_for_axes(rules, (16384, 6144), ("ffn", "embed"), "w_down")
    assert spec == PartitionSpec("model", "data")
    spec2 = spec_for_axes(rules, (16384, 16384), ("ffn", "vocab"), "x")
    assert spec2 == PartitionSpec("model", None)  # vocab→model already used


def test_fsdp_layers_mode_prefers_layer_dim():
    rules = make_rules(_mesh(), "train", fsdp_layers=True)
    spec = spec_for_axes(rules, (48, 6144, 16384),
                         ("layers", "embed", "ffn"), "stacked")
    assert spec == PartitionSpec("data", None, "model")


def test_serve_rules_no_fsdp():
    rules = make_rules(_mesh(), "decode")
    spec = spec_for_axes(rules, (6144, 48, 128),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec == PartitionSpec(None, "model", None)


def test_batch_axes_multipod():
    assert batch_axes(_mesh((2, 16, 16), ("pod", "data", "model"))) == \
        ("pod", "data")
    assert batch_axes(_mesh()) == ("data",)


def test_qwen25_qheads_unsharded_on_16way_model():
    """qwen2.5-14b's 40 q-heads on a 16-way model axis: 40 % 16 != 0, so
    the head dim must stay unsharded with the drop recorded — never an
    invalid spec."""
    cfg = get_config("qwen2.5-14b")
    assert cfg.n_heads == 40
    rules = make_rules(_mesh((1, 16)), "train")
    spec = spec_for_axes(rules, (cfg.d_model, cfg.n_heads, cfg.d_head),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec[1] is None
    assert ("w_q", "heads", cfg.n_heads) in rules.dropped
    # what DID shard still divides: embed 5120 over data=1
    assert cfg.d_model % rules.axis_size("data") == 0


def test_arctic_56_stays_unsharded_on_16way_model():
    """arctic-480b's 56-way dim (its head count, and the ISSUE's expert
    example) on a 16-way model axis: 56 % 16 != 0 → replicated + drop
    recorded; a 128-expert dim on the same mesh does shard."""
    cfg = get_config("arctic-480b")
    assert cfg.n_heads == 56 and cfg.n_experts == 128
    rules = make_rules(_mesh((1, 16)), "train")
    spec = spec_for_axes(rules, (cfg.d_model, cfg.n_heads, cfg.d_head),
                         ("embed", "heads", "head_dim"), "w_q")
    assert spec[1] is None
    assert ("w_q", "heads", 56) in rules.dropped
    spec56 = spec_for_axes(rules, (56, cfg.d_model, cfg.d_ff),
                           ("experts", "embed", "ffn"), "w_up_56")
    assert spec56[0] is None
    assert ("w_up_56", "experts", 56) in rules.dropped
    rules2 = make_rules(_mesh((1, 16)), "train")
    spec128 = spec_for_axes(rules2, (cfg.n_experts, cfg.d_model, cfg.d_ff),
                            ("experts", "embed", "ffn"), "w_up")
    assert spec128[0] == "model"          # 128 % 16 == 0: shards fine


def test_every_guarded_spec_entry_divides():
    """The guard's contract — any non-None entry divides its dim — over
    a sweep of awkward shapes (this is what makes specs jit-valid)."""
    rules = make_rules(_mesh((3, 16)), "train")
    for dim0 in (1, 7, 40, 48, 56, 96, 128):
        for dim1 in (1, 6, 9, 21, 48):
            spec = spec_for_axes(rules, (dim1, dim0, 128),
                                 ("embed", "heads", "head_dim"),
                                 f"w_{dim0}_{dim1}")
            for entry, dim in zip(spec, (dim1, dim0, 128)):
                if entry is not None:
                    assert dim % rules.axis_size(entry) == 0


def test_long500k_batch1_replicates():
    from repro.distributed.sharding import batch_specs
    import jax
    rules = make_rules(_mesh(), "decode")
    cfg = get_config("rwkv6-3b")
    specs = batch_specs(rules, cfg, "decode",
                        {"tokens": jax.ShapeDtypeStruct((1,), np.int32)})
    assert specs["tokens"].spec == PartitionSpec(None)
