"""Static plan verifier: golden diagnostics + adversarial mutation fuzz.

The contract under test (ISSUE 7 tentpole):

  * every violation class carries an op-indexed diagnostic — the golden
    tests seed one mutation per class and pin kind/var/op_index,
  * the verifier has **no false negatives** against the runtime: any
    mutant the executor-vs-host-oracle diff catches (exception or wrong
    output) is statically flagged as an error (mutation fuzzer),
  * lints never fail verification — the paper's naive-3MM redundancies
    (duplicate upload of E/F, dead store of E/F) surface as lints on a
    plan that still verifies ok,
  * ``PlanVerificationError`` is a ``PlanExecutionError``: callers
    guarding ``execute()`` see one exception family whether the failure
    is caught statically (``REPRO_VERIFY=1``) or at runtime.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (Plan, PlanExecutionError, PlanOp,
                        PlanVerificationError, execute, naive_plan, plan,
                        run_host_oracle, verify_plan)
from repro.core.ir import AdvancedLoad, DelegateStore, Release
from repro.core.verify import VIOLATION_KINDS
from repro.optim import plan_step_program
from repro.polybench import build


def clone(pl, ops=None):
    """A mutable copy sharing program/groups/io_table; drops any cached
    compiled artifact so the mutant is re-lowered from its own ops."""
    return Plan(program=pl.program,
                ops=list(pl.ops if ops is None else ops),
                groups=pl.groups, io_table=pl.io_table,
                meta={k: v for k, v in pl.meta.items()
                      if k != "_compiled"})


def _find(pl, cls, **attrs):
    """(index, directive) of the first directive of type ``cls`` whose
    attributes match ``attrs``."""
    for i, op in enumerate(pl.ops):
        if op.kind == "directive" and isinstance(op.directive, cls):
            if all(getattr(op.directive, k) == v for k, v in attrs.items()):
                return i, op.directive
    raise AssertionError(f"no {cls.__name__} matching {attrs}")


def _regroup(pl, i):
    d = pl.ops[i].directive
    m = clone(pl)
    m.ops[i] = PlanOp("directive",
                      directive=dataclasses.replace(d, group=d.group + 7))
    return m


@pytest.fixture(scope="module")
def p3mm():
    return build("3mm", n=16)[0]


class TestGoldenDiagnostics:
    """One seeded mutation per violation class, diagnostics pinned."""

    def test_async_race_regrouped_load(self, p3mm):
        """A load moved to a foreign group: the consuming callsite no
        longer completes its in-flight upload — race at the block op."""
        pl = plan(p3mm)
        i, d = _find(pl, AdvancedLoad, var="A")
        assert d.asynchronous and d.stream
        rep = verify_plan(_regroup(pl, i), collect_lints=False)
        assert not rep.ok
        v = next(v for v in rep.errors if v.kind == "async-race")
        assert v.var == "A" and v.severity == "error"
        # anchored at the consuming block op, after the load
        assert i < v.op_index < len(pl.ops)
        assert pl.ops[v.op_index].kind == "block"
        assert "in flight" in v.message

    def test_stale_host_read_deleted_store(self, p3mm):
        """Store of G deleted: the host consumer reads a host copy the
        device-dirty value never reached."""
        pl = plan(p3mm)
        m = clone(pl, [op for op in pl.ops
                       if not (op.kind == "directive"
                               and isinstance(op.directive, DelegateStore))])
        rep = verify_plan(m, collect_lints=False)
        v = next(v for v in rep.errors if v.kind == "stale-host-read")
        assert v.var == "G"
        assert m.ops[v.op_index].kind == "block"
        assert "missing delegatedstore" in v.message

    def test_use_after_release_early_release(self, p3mm):
        """A Release inserted after the first codelet frees the loaded
        inputs the later codelets still read."""
        pl = plan(p3mm)
        first_blk = next(i for i, op in enumerate(pl.ops)
                         if op.kind == "block")
        ops = list(pl.ops)
        ops.insert(first_blk + 1,
                   PlanOp("directive", directive=Release(group=0)))
        rep = verify_plan(clone(pl, ops), collect_lints=False)
        vs = [v for v in rep.errors if v.kind == "use-after-release"]
        assert vs and all(v.op_index > first_blk + 1 for v in vs)
        assert {v.var for v in vs} == {"C", "D"}

    def test_use_after_donation_gemm_inout(self):
        """gemm's C is inout: regrouping its load leaves the h2d DMA live
        when donation recycles the buffer — flagged only under donate."""
        p = build("gemm", n=16)[0]
        pl = plan(p)
        i, _ = _find(pl, AdvancedLoad, var="C")
        m = _regroup(pl, i)
        rep = verify_plan(m, donate=True, collect_lints=False)
        v = next(v for v in rep.errors if v.kind == "use-after-donation")
        assert v.var == "C" and m.ops[v.op_index].kind == "block"
        assert "donat" in v.message
        # same mutant without donation: the race remains, donation
        # hazard does not
        rep_nd = verify_plan(m, donate=False, collect_lints=False)
        assert "use-after-donation" not in rep_nd.kinds()
        assert "async-race" in rep_nd.kinds()

    def test_placement_gap_deleted_load(self, p3mm):
        pl = plan(p3mm)
        i, _ = _find(pl, AdvancedLoad, var="A")
        rep = verify_plan(clone(pl, pl.ops[:i] + pl.ops[i + 1:]),
                          collect_lints=False)
        v = next(v for v in rep.errors if v.kind == "placement-gap")
        assert v.var == "A" and "missing advancedload" in v.message

    def test_illegal_kernel_tile(self):
        from repro.optim import attention_step_program
        p = attention_step_program(n_steps=1)
        pl = plan(p)
        rep = verify_plan(
            pl, kernel_variants={"flash_attention":
                                 {"block_q": 77, "block_k": 64}},
            collect_lints=False)
        v = next(v for v in rep.errors if v.kind == "illegal-kernel-tile")
        assert "flash_attention" in v.message and "77" in v.message

    def test_malformed_unclosed_loop(self):
        pl = plan(plan_step_program(n_steps=2))
        m = clone(pl, [op for op in pl.ops if op.kind != "loop_end"])
        rep = verify_plan(m, collect_lints=False)
        v = next(v for v in rep.errors if v.kind == "malformed")
        assert "never closed" in v.message

    def test_redundant_directive_is_lint_not_error(self, p3mm):
        """A duplicated upload is waste, not breakage: the report stays
        ok and the finding is a lint."""
        pl = plan(p3mm)
        i, _ = _find(pl, AdvancedLoad, var="A")
        rep = verify_plan(clone(pl, pl.ops[:i] + [pl.ops[i]] + pl.ops[i:]))
        assert rep.ok and not rep.errors
        assert any(v.kind == "redundant-directive"
                   and v.severity == "lint" and v.var == "A"
                   for v in rep.lints)

    def test_naive_3mm_reproduces_paper_lints(self, p3mm):
        """The paper's 3MM insight: the naive policy uploads E and F that
        are already device-resident and downloads them for no host
        reader.  The verifier surfaces exactly those as lints."""
        rep = verify_plan(naive_plan(p3mm))
        assert rep.ok
        lint_vars = {v.var for v in rep.lints
                     if v.kind == "redundant-directive"}
        assert lint_vars == {"E", "F"}
        msgs = " ".join(v.message for v in rep.lints)
        assert "duplicate upload" in msgs and "dead store" in msgs

    def test_every_kind_is_registered(self):
        assert set(VIOLATION_KINDS) >= {
            "async-race", "stale-host-read", "use-after-release",
            "use-after-donation", "placement-gap", "illegal-kernel-tile",
            "redundant-directive", "malformed"}

    def test_violation_str_is_op_indexed(self, p3mm):
        pl = plan(p3mm)
        i, _ = _find(pl, AdvancedLoad, var="A")
        rep = verify_plan(clone(pl, pl.ops[:i] + pl.ops[i + 1:]),
                          collect_lints=False)
        s = str(rep.errors[0])
        assert "@op" in s and "placement-gap" in s


class TestExceptionContract:
    def test_verification_error_is_execution_error(self, p3mm):
        assert issubclass(PlanVerificationError, PlanExecutionError)
        pl = plan(p3mm)
        i, _ = _find(pl, AdvancedLoad, var="A")
        broken = clone(pl, pl.ops[:i] + pl.ops[i + 1:])
        with pytest.raises(PlanExecutionError) as ei:
            execute(broken, backend="numpy", verify=True)
        assert isinstance(ei.value, PlanVerificationError)
        assert ei.value.report.errors

    def test_verify_off_reaches_runtime_check(self, p3mm):
        """verify=False skips the static pass; the runtime's own
        residency check still refuses the broken plan."""
        pl = plan(p3mm)
        i, _ = _find(pl, AdvancedLoad, var="A")
        broken = clone(pl, pl.ops[:i] + pl.ops[i + 1:])
        with pytest.raises(PlanExecutionError) as ei:
            execute(broken, backend="numpy", verify=False)
        assert not isinstance(ei.value, PlanVerificationError)

    def test_planner_records_verdict(self, p3mm):
        verdict = plan(p3mm).meta["verify"]
        assert verdict["ok"] is True and verdict["n_errors"] == 0
        assert verdict["checked_ops"] > 0

    def test_emitter_annotates_verdict(self, p3mm):
        from repro.core import emit
        assert "#pragma omp2hmpp verified, ok=true" in emit(plan(p3mm))


# -- adversarial mutation fuzz ---------------------------------------------

def _mutants(pl):
    """Deterministic single-op mutations over every directive position:
    delete, duplicate, regroup (+7), restream (+1), swap-adjacent."""
    ops = pl.ops
    didx = [i for i, op in enumerate(ops) if op.kind == "directive"]
    for i in didx:
        yield f"del@{i}", ops[:i] + ops[i + 1:]
        yield f"dup@{i}", ops[:i] + [ops[i]] + ops[i:]
        d = ops[i].directive
        if hasattr(d, "group"):
            yield (f"regroup@{i}",
                   ops[:i] + [PlanOp("directive",
                                     directive=dataclasses.replace(
                                         d, group=d.group + 7))]
                   + ops[i + 1:])
        if getattr(d, "stream", None):
            yield (f"restream@{i}",
                   ops[:i] + [PlanOp("directive",
                                     directive=dataclasses.replace(
                                         d, stream=d.stream + 1))]
                   + ops[i + 1:])
    for i in didx:
        if i + 1 in didx:
            yield f"swap@{i}", ops[:i] + [ops[i + 1], ops[i]] + ops[i + 2:]


def _oracle_catches(program, mutant, oracle):
    """Ground truth: does the runtime (numpy backend, residency checks
    on, static verify OFF) reject the mutant or corrupt its outputs?"""
    try:
        out, _ = execute(mutant, backend="numpy", check=True, verify=False)
    except Exception as e:                 # noqa: BLE001 — any crash counts
        return f"{type(e).__name__}"
    for k in program.outputs:
        if not np.allclose(out[k], oracle[k], rtol=1e-5, atol=1e-6):
            return f"mismatch:{k}"
    return None


class TestMutationFuzzer:
    """No false negatives: every mutant the executor-vs-oracle diff
    catches must already be a verifier error."""

    PROGRAMS = ("3mm", "gemm", "mvt")

    def test_verifier_flags_every_oracle_caught_mutant(self):
        total, false_negatives = 0, []
        for name in self.PROGRAMS:
            p = build(name, n=16)[0]
            oracle = run_host_oracle(p)
            for planner in (plan, naive_plan):
                pl = planner(p)
                for label, mops in _mutants(pl):
                    total += 1
                    m = clone(pl, mops)
                    rep = verify_plan(m, collect_lints=False)
                    caught = _oracle_catches(p, m, oracle)
                    if caught and not rep.errors:
                        false_negatives.append(
                            f"{name}/{pl.meta['policy']}/{label}: "
                            f"runtime caught [{caught}], verifier ok")
        assert total >= 200, f"mutation corpus too small: {total}"
        assert not false_negatives, "\n".join(false_negatives)


class TestHypothesisFuzzer:
    """Randomized mutation chains (1-3 stacked single-op mutations) keep
    the no-false-negative invariant.  Skipped where hypothesis is not
    installed (it is in requirements-dev.txt, so CI always runs this)."""

    def test_stacked_mutations_keep_invariant(self):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        p = build("3mm", n=16)[0]
        oracle = run_host_oracle(p)
        base = plan(p)
        pool = list(_mutants(base))

        @hyp.given(st.lists(st.integers(0, len(pool) - 1),
                            min_size=1, max_size=3))
        @hyp.settings(max_examples=60, deadline=None)
        def run(picks):
            m = clone(base)
            for j in picks:
                # re-derive the mutation on the *current* ops when the
                # index is still a directive; else skip that pick
                label, _ = pool[j]
                kind, pos = label.split("@")
                pos = int(pos)
                ops = m.ops
                if pos >= len(ops) or ops[pos].kind != "directive":
                    continue
                for lbl, mops in _mutants(m):
                    if lbl == f"{kind}@{pos}":
                        m = clone(m, mops)
                        break
            rep = verify_plan(m, collect_lints=False)
            caught = _oracle_catches(p, m, oracle)
            assert not (caught and not rep.errors), (
                f"runtime caught [{caught}] but verifier passed "
                f"{[str(o.directive) for o in m.ops if o.kind == 'directive']}")

        run()


class TestMeshPlacement:
    """ISSUE 9: sharded-plan validation.  The mesh record is plain JSON
    (the tuner's meta["mesh"]), so these run without any device mesh."""

    def _mesh(self, specs, dropped=(), shape=(2, 4),
              axes=("data", "model")):
        return {"shape": list(shape), "axes": list(axes),
                "placement": "fsdp", "n_devices": 8,
                "specs": specs, "dropped": [list(d) for d in dropped]}

    def test_valid_sharded_plan_verifies_clean(self, p3mm):
        pl = plan(p3mm)
        mesh = self._mesh({v: ["data", None] for v in "ABCDEF"})
        rep = verify_plan(pl, mesh=mesh)
        assert rep.ok and not rep.errors

    def test_meta_mesh_is_picked_up_by_default(self, p3mm):
        pl = plan(p3mm)
        m = clone(pl)
        m.meta["mesh"] = self._mesh({"nosuchvar": ["data"]})
        rep = verify_plan(m)
        assert any(v.kind == "mesh-placement" for v in rep.errors)

    def test_unknown_var_in_spec(self, p3mm):
        pl = plan(p3mm)
        rep = verify_plan(pl, mesh=self._mesh({"zzz": ["data"]}))
        v = next(v for v in rep.errors if v.kind == "mesh-placement")
        assert v.var == "zzz"

    def test_unknown_mesh_axis(self, p3mm):
        pl = plan(p3mm)
        rep = verify_plan(pl, mesh=self._mesh({"A": ["expert", None]}))
        assert any(v.kind == "mesh-placement" and v.var == "A"
                   for v in rep.errors)

    def test_non_dividing_shard_rejected(self, p3mm):
        """3mm n=16: dim 16 over a 3-way axis does not divide — the
        divisibility guard should have dropped it upstream."""
        pl = plan(p3mm)
        mesh = self._mesh({"A": ["model", None]}, shape=(2, 3))
        rep = verify_plan(pl, mesh=mesh)
        assert any(v.kind == "mesh-placement" and v.var == "A"
                   for v in rep.errors)

    def test_drop_without_spec_is_a_gap(self, p3mm):
        """A divisibility-guard drop whose var then has NO spec at all:
        the placement has a gap (the var's distribution is undefined)."""
        pl = plan(p3mm)
        mesh = self._mesh({"A": ["data", None]},
                          dropped=[("B", "heads", 40)])
        rep = verify_plan(pl, mesh=mesh)
        assert any(v.kind == "mesh-placement" and v.var == "B"
                   for v in rep.errors)
        # an explicit replicated spec closes the gap
        mesh2 = self._mesh({"A": ["data", None], "B": []},
                           dropped=[("B", "heads", 40)])
        assert verify_plan(pl, mesh=mesh2).ok

    def test_sharded_read_is_a_sync_point(self, p3mm):
        """The async-race golden mutation (load regrouped away from its
        callsite) is NOT a race when the operand is sharded: the SPMD
        dispatch waits on every shard of the distributed upload."""
        pl = plan(p3mm)
        i, d = _find(pl, AdvancedLoad, var="A")
        assert d.asynchronous
        m = _regroup(pl, i)
        assert any(v.kind == "async-race"
                   for v in verify_plan(m, collect_lints=False).errors)
        mesh = self._mesh({"A": ["data", None]})
        rep = verify_plan(m, collect_lints=False, mesh=mesh)
        assert not any(v.kind == "async-race" for v in rep.errors)
