"""Serving benchmark: continuous batching vs the static-batch baseline.

Both modes replay the IDENTICAL seeded open-loop trace (Poisson
arrivals, skewed generation-length mix) through the same ``ServeRuntime``
— same resident params, same compiled prefill/decode/insert programs —
so the measured gap is purely the scheduling discipline:

* ``static``    — requests may only join when the decode batch has fully
                  drained, so every group runs to its slowest member
                  (head-of-line blocking on the long tail);
* ``continuous``— freed rows are backfilled at any step boundary, so the
                  batch stays occupied.

A short warmup trace runs first (excluded from timing) to compile every
shape bucket and the decode step.  The second, warm engine run also
demonstrates the persistent plan cache: every bucket is a tunecache hit,
zero online measurements.

Invariants checked on every run (``--check`` also gates the speedup):
all requests finish, none dropped, p99 latency finite, zero KV-slot
leaks, and — after warmup — zero online tune measurements.

    PYTHONPATH=src python benchmarks/serve_bench.py --quick
    PYTHONPATH=src python benchmarks/serve_bench.py --check   # CI gate

Writes ``BENCH_serve_<YYYYMMDD>.json`` at the repo root (CI uploads
``BENCH_*.json`` artifacts).
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from repro.configs import get_config, reduced
from repro.serve import Engine, ServeRuntime, make_trace

# mostly-short with a long tail: the traffic shape where static batching
# pays its head-of-line penalty
GEN_MIX = ((4, 0.50), (8, 0.25), (112, 0.25))
PROMPT_MIX = ((8, 0.70), (16, 0.30))
SPEEDUP_FLOOR = 1.5


def run_mode(rt, reqs, *, join_policy: str, capacity: int):
    eng = Engine(rt, capacity=capacity, join_policy=join_policy,
                 policy="fcfs")
    # fresh copies: Request objects are mutated by the engine
    replay = [r.__class__(rid=r.rid, prompt=r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens,
                          arrival_s=r.arrival_s) for r in reqs]
    rep = eng.run(replay, respect_arrivals=False)
    rep["leaked_slots"] = eng.pool.in_use        # assert_no_leaks already ran
    return rep


def check(rep, n_expected: int) -> None:
    assert rep["n_requests"] == n_expected, (rep["n_requests"], n_expected)
    assert rep["dropped"] == 0
    assert rep["leaked_slots"] == 0
    assert math.isfinite(rep["latency_p99_s"]), rep["latency_p99_s"]


def bench(*, arch: str, n_requests: int, capacity: int, max_seq: int,
          seed: int, gate: bool):
    cfg = reduced(get_config(arch))
    rt = ServeRuntime(cfg, max_seq=max_seq, seed=seed)

    trace = make_trace(cfg, n_requests=n_requests, rate_rps=1e6, seed=seed,
                       prompt_mix=PROMPT_MIX, gen_mix=GEN_MIX,
                       max_seq=max_seq)

    # warmup: compile + measure every shape the timed runs will hit
    # (excluded from timing) — one request per distinct prompt length for
    # full bucket coverage, same trace length and same max generation
    # length so the decode/park jits are byte-identical.
    from repro.serve import Request
    lens = sorted({r.prompt_len for r in trace})
    gen_cap = max(r.max_new_tokens for r in trace)

    def _prompt(L):
        return (np.zeros((L, cfg.d_model), np.float32)
                if cfg.input_embeds else np.zeros((L,), np.int32))
    warm = [Request(rid=1000 + i, prompt=_prompt(lens[i % len(lens)]),
                    max_new_tokens=gen_cap if i == 0 else 2)
            for i in range(len(trace))]
    run_mode(rt, warm, join_policy="continuous", capacity=capacity)

    meas_before = rt.tune_measurements
    cont = run_mode(rt, trace, join_policy="continuous", capacity=capacity)
    stat = run_mode(rt, trace, join_policy="static", capacity=capacity)
    check(cont, n_requests)
    check(stat, n_requests)
    warm_measurements = rt.tune_measurements - meas_before

    ratio = cont["requests_per_s"] / max(stat["requests_per_s"], 1e-9)
    row = {
        "arch": cfg.name,
        "n_requests": n_requests,
        "capacity": capacity,
        "max_seq": max_seq,
        "seed": seed,
        "speedup_requests_per_s": ratio,
        "warm_tune_measurements": warm_measurements,
        "continuous": {k: cont[k] for k in (
            "requests_per_s", "tokens_per_s", "latency_p50_s",
            "latency_p99_s", "occupancy", "steps", "fetch_batches")},
        "static": {k: stat[k] for k in (
            "requests_per_s", "tokens_per_s", "latency_p50_s",
            "latency_p99_s", "occupancy", "steps")},
        "tune": cont["tune"],
        "pool": cont["pool"],
    }
    print(f"[serve_bench] {cfg.name}: continuous "
          f"{cont['requests_per_s']:.1f} req/s (occ {cont['occupancy']:.2f})"
          f" vs static {stat['requests_per_s']:.1f} req/s "
          f"(occ {stat['occupancy']:.2f}) -> {ratio:.2f}x; "
          f"warm tune measurements: {warm_measurements}")

    assert warm_measurements == 0, (
        f"warm run still measured {warm_measurements} buckets — the "
        f"shape-bucketed plan cache is not being hit")
    if gate:
        assert ratio >= SPEEDUP_FLOOR, (
            f"continuous batching speedup {ratio:.2f}x below the "
            f"{SPEEDUP_FLOOR}x floor")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b,rwkv6-3b",
                    help="comma-separated arch list (one bench row each)")
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: ~20 requests, no speedup gate")
    ap.add_argument("--check", action="store_true",
                    help="gate: continuous >= 1.5x static requests/s")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.quick:
        args.n_requests = 20
        args.capacity = min(args.capacity, 4)

    rows = []
    for arch in [a.strip() for a in args.arch.split(",") if a.strip()]:
        rows.append(bench(arch=arch, n_requests=args.n_requests,
                          capacity=args.capacity, max_seq=args.max_seq,
                          seed=args.seed, gate=args.check))
    path = args.out or f"BENCH_serve_{time.strftime('%Y%m%d')}.json"
    snap = {"date": time.strftime("%Y-%m-%d"), "bench": "serve",
            "rows": rows,
            # single-arch "row" kept so older trajectory diffs keep working
            "row": rows[0]}
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=float)
    print(f"[serve_bench] snapshot written to {path}")
    return rows


if __name__ == "__main__":
    main()
