"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Output: ``name,us_per_call,derived`` CSV rows.
  table2_3mm          — paper Table 2 (generated 3MM schedule)
  fig4_advancedload   — paper Fig. 4 (upload hoisting)
  fig5_delegatestore  — paper Fig. 5 (download sinking)
  fig6_<problem>      — paper Fig. 6 (Polybench suite speedups)
  train_overlap       — beyond-paper: planner schedule on the train loop
  roofline summary    — see EXPERIMENTS.md §Roofline (from the dry-run)
"""
from __future__ import annotations

def main() -> None:
    print("name,us_per_call,derived")

    from benchmarks import table2_3mm
    row = table2_3mm.run(show_source=False)
    extra = ";".join(
        f"{k}={v if not isinstance(v, float) else round(v, 2)}"
        for k, v in row.items() if k != "wall_opt_ms")
    print(f"table2_3mm,{row['wall_opt_ms'] * 1e3:.0f},{extra}")

    from benchmarks import directive_micro
    for bench in (directive_micro.bench_advancedload,
                  directive_micro.bench_delegatestore):
        r = bench()
        extra = ";".join(f"{k}={v if not isinstance(v, float) else round(v, 2)}"
                         for k, v in r.items()
                         if k not in ("name", "t_opt_ms"))
        print(f"{r['name']},{r['t_opt_ms'] * 1e3:.0f},{extra}")

    from benchmarks import polybench_suite
    for r in polybench_suite.run_suite():
        print(f"fig6_{r['problem']},{r['t_omp2hmpp_ms'] * 1e3:.0f},"
              f"speedup_seq={r['speedup_vs_seq']:.2f}x;"
              f"speedup_naive={r['speedup_vs_naive']:.2f}x;"
              f"hand_gap={r['hand_vs_omp2hmpp']:.2f}x;"
              f"transfers={r['transfers_opt']}/{r['transfers_naive']};"
              f"bytes_saved={r['bytes_saved_vs_naive']}")

    from benchmarks import train_overlap
    r = train_overlap.run()
    print(f"{r['name']},"
          f"{r['t_planned_ms'] * 1e3 / train_overlap.STEPS:.0f},"
          f"speedup={r['speedup']:.2f}x;sync_ms={r['t_sync_ms']:.0f};"
          f"planned_ms={r['t_planned_ms']:.0f};"
          f"final_loss={r['final_loss']:.3f}")


if __name__ == "__main__":
    main()
