"""Benchmark trajectory: diff the last two dated tuning snapshots.

``directive_micro --tune`` writes ``BENCH_<YYYYMMDD>.json`` at the repo
root on every run; committing them gives the repo a measured performance
trajectory.  This tool compares the two most recent snapshots
program-by-program and flags regressions:

* ``measured_ms``  > 10% slower  → regression (the real gate)
* ``predicted_ms`` > 10% higher  → cost-model drift note (only a
  regression when the cost-model version did NOT change between the two
  snapshots — a version bump legitimately reprices everything)
* ``energy_mj`` / ``peak_mb`` (the ISSUE-10 objective columns of the
  chosen plan) > 10% higher → gated like ``predicted_ms``: both are
  model outputs, so an intentional COST_MODEL_VERSION bump downgrades
  their drift to a note instead of flagging it
* a program present before but missing now → coverage regression

    PYTHONPATH=src python benchmarks/trajectory.py            # report
    PYTHONPATH=src python benchmarks/trajectory.py --gate     # exit 1 on
                                                              # regression

With fewer than two snapshots there is nothing to diff: the tool prints
a note and exits 0 (first run on a fresh clone must not fail CI).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

REGRESSION_PCT = 10.0
_SNAP_RE = re.compile(r"BENCH_(\d{8})\.json$")


def find_snapshots(root: str = ".") -> List[str]:
    """Dated tune snapshots, oldest → newest (serve snapshots —
    ``BENCH_serve_*`` — have their own schema and are excluded)."""
    paths = [p for p in glob.glob(os.path.join(root, "BENCH_*.json"))
             if _SNAP_RE.search(os.path.basename(p))]
    return sorted(paths, key=lambda p: _SNAP_RE.search(p).group(1))


def _load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def _pct(new: float, old: float) -> Optional[float]:
    if not old:
        return None
    return (new - old) / old * 100.0


def diff(prev: Dict, curr: Dict) -> Tuple[List[str], List[str]]:
    """(regressions, notes) between two snapshot payloads."""
    regressions: List[str] = []
    notes: List[str] = []
    same_cost_model = (prev.get("cost_model_version")
                       == curr.get("cost_model_version"))
    if not same_cost_model:
        notes.append(
            f"cost model {prev.get('cost_model_version')} -> "
            f"{curr.get('cost_model_version')}: predicted_ms drift is "
            "expected and not gated")
    p_prog = prev.get("programs", {})
    c_prog = curr.get("programs", {})
    for name in sorted(p_prog):
        if name not in c_prog:
            regressions.append(f"{name}: present in previous snapshot but "
                               "missing now (coverage regression)")
            continue
        old, new = p_prog[name], c_prog[name]
        # model-derived columns (predicted/energy/memory) gate only when
        # the cost model did not change; a missing key in the OLD
        # snapshot (pre-multi-objective) yields _pct None and is skipped
        for key, gated in (("measured_ms", True),
                           ("predicted_ms", same_cost_model),
                           ("energy_mj", same_cost_model),
                           ("peak_mb", same_cost_model)):
            d = _pct(float(new.get(key) or 0.0), float(old.get(key) or 0.0))
            if d is None:
                continue
            line = (f"{name}: {key} {old[key]:.3f} -> {new[key]:.3f} "
                    f"({d:+.1f}%)")
            if d > REGRESSION_PCT and gated:
                regressions.append(line)
            elif abs(d) > REGRESSION_PCT:
                notes.append(line)
    for name in sorted(set(c_prog) - set(p_prog)):
        notes.append(f"{name}: new program (no previous measurement)")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".", help="where BENCH_*.json live")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 when a >10%% measured regression is found")
    args = ap.parse_args(argv)

    snaps = find_snapshots(args.root)
    if len(snaps) < 2:
        print(f"[trajectory] {len(snaps)} snapshot(s) found — need two to "
              "diff; nothing to do")
        return 0
    prev_path, curr_path = snaps[-2], snaps[-1]
    prev, curr = _load(prev_path), _load(curr_path)
    print(f"[trajectory] {os.path.basename(prev_path)} -> "
          f"{os.path.basename(curr_path)}")
    regressions, notes = diff(prev, curr)
    for n in notes:
        print(f"  note: {n}")
    for r in regressions:
        print(f"  REGRESSION: {r}")
    if not regressions and not notes:
        print("  all programs within the 10% envelope")
    if regressions and args.gate:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
