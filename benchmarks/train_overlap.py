"""Beyond-paper: the planner's schedule applied to a real training loop.

Compares wall-clock of N training steps with
    sync     — batch built + uploaded synchronously inside the loop,
               metrics fetched every step (the naive schedule), vs
    planned  — prefetch thread uploads batch i+1 during step i
               (advancedload) and metrics are fetched once at the end
               (delegatestore sunk ALAP).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data import PrefetchIterator, SyntheticLM
from repro.launch.train import make_train_step
from repro.models import Transformer
from repro.optim import default_optimizer

STEPS = 20
BATCH, SEQ = 8, 128


def run(arch: str = "internlm2-20b"):
    cfg = reduced(get_config(arch))
    model = Transformer(cfg)
    opt = default_optimizer(cfg)
    src = SyntheticLM(cfg, BATCH, SEQ, seed=0)
    step_fn = make_train_step(model, opt)

    def fresh():
        params = model.init(jax.random.key(0))
        return params, opt.init(params)

    # --- sync schedule --------------------------------------------------
    params, opt_state = fresh()
    batch0 = {k: jax.device_put(v) for k, v in src.batch_at(0).items()}
    params, opt_state, m = step_fn(params, opt_state, batch0)  # compile
    float(m["loss"])
    t0 = time.perf_counter()
    for i in range(STEPS):
        host_batch = src.batch_at(i)                       # host produce
        dev_batch = {k: jax.device_put(v)
                     for k, v in host_batch.items()}       # upload (sync pt)
        params, opt_state, metrics = step_fn(params, opt_state, dev_batch)
        float(metrics["loss"])                             # fetch every step
    t_sync = time.perf_counter() - t0

    # --- planned schedule ------------------------------------------------
    params, opt_state = fresh()
    params, opt_state, m = step_fn(params, opt_state, batch0)
    float(m["loss"])
    it = PrefetchIterator(src, start_index=0, depth=2)     # advancedload
    t0 = time.perf_counter()
    metrics = None
    for i in range(STEPS):
        dev_batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, dev_batch)
    loss = float(metrics["loss"])                          # one late fetch
    t_planned = time.perf_counter() - t0
    it.close()

    return {
        "name": "train_overlap",
        "t_sync_ms": t_sync * 1e3,
        "t_planned_ms": t_planned * 1e3,
        "speedup": t_sync / t_planned,
        "final_loss": loss,
    }


def main():
    r = run()
    print(f"{r['name']},{r['t_planned_ms'] * 1e3 / STEPS:.0f},"
          f"speedup={r['speedup']:.2f}x;sync_ms={r['t_sync_ms']:.0f};"
          f"planned_ms={r['t_planned_ms']:.0f}")
    return r


if __name__ == "__main__":
    main()
