"""Beyond-paper: the planner's schedule applied to a real training loop.

Compares wall-clock of N training steps with
    sync     — batch built + uploaded synchronously inside the loop,
               metrics fetched every step (the naive schedule), vs
    planned  — prefetch thread uploads batch i+1 during step i
               (advancedload) and metrics are fetched once at the end
               (delegatestore sunk ALAP).

``run_plan_executor`` additionally runs the same schedule as an explicit
block-``Program`` (``plan_step_program``) through the plan executor in
three execution modes — interpreted, compiled (per-iteration segment
dispatch), and compiled+loop (the whole step loop rolled into one
``lax.fori_loop`` launch) — isolating how much of the step loop's cost
is Python directive dispatch vs the schedule itself.
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_config, reduced
from repro.core import execute, naive_plan, plan
from repro.data import PrefetchIterator, SyntheticLM
from repro.launch.train import make_train_step
from repro.models import Transformer
from repro.optim import default_optimizer, plan_step_program

STEPS = 20
BATCH, SEQ = 8, 128


def run(arch: str = "internlm2-20b"):
    cfg = reduced(get_config(arch))
    model = Transformer(cfg)
    opt = default_optimizer(cfg)
    src = SyntheticLM(cfg, BATCH, SEQ, seed=0)
    step_fn = make_train_step(model, opt)

    def fresh():
        params = model.init(jax.random.key(0))
        return params, opt.init(params)

    # --- sync schedule --------------------------------------------------
    params, opt_state = fresh()
    batch0 = {k: jax.device_put(v) for k, v in src.batch_at(0).items()}
    params, opt_state, m = step_fn(params, opt_state, batch0)  # compile
    float(m["loss"])
    t0 = time.perf_counter()
    for i in range(STEPS):
        host_batch = src.batch_at(i)                       # host produce
        dev_batch = {k: jax.device_put(v)
                     for k, v in host_batch.items()}       # upload (sync pt)
        params, opt_state, metrics = step_fn(params, opt_state, dev_batch)
        float(metrics["loss"])                             # fetch every step
    t_sync = time.perf_counter() - t0

    # --- planned schedule ------------------------------------------------
    params, opt_state = fresh()
    params, opt_state, m = step_fn(params, opt_state, batch0)
    float(m["loss"])
    it = PrefetchIterator(src, start_index=0, depth=2)     # advancedload
    t0 = time.perf_counter()
    metrics = None
    for i in range(STEPS):
        dev_batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, dev_batch)
    loss = float(metrics["loss"])                          # one late fetch
    t_planned = time.perf_counter() - t0
    it.close()

    return {
        "name": "train_overlap",
        "t_sync_ms": t_sync * 1e3,
        "t_planned_ms": t_planned * 1e3,
        "speedup": t_sync / t_planned,
        "final_loss": loss,
    }


def run_plan_executor(n_steps: int = 64, reps: int = 3):
    """The miniature train loop as a block program, every cell of
    {naive, optimized} x {interpreted, compiled, compiled+loop}, plus
    the plan-space explorer's winner (``policy="auto"``) as a fourth
    row — the tuner must never lose to the fixed schedules it
    enumerates.  All wall times are steady-state: the jits are warmed
    before timing and one-time plan lowering is surfaced separately
    (``compile_ms``, from ``ExecStats.compile_time``)."""
    p = plan_step_program(n_steps=n_steps)
    plans = {"naive": naive_plan(p), "opt": plan(p)}
    modes = (("interpreted", dict(mode="interpreted")),
             ("compiled", dict(mode="compiled", fuse_loops=False)),
             ("compiled_loop", dict(mode="compiled", fuse_loops=True)))
    out = {"name": "train_plan_executor", "n_steps": n_steps}
    compile_ms = 0.0
    for pname, pl in plans.items():
        for label, kw in modes:
            _, s0 = execute(pl, **kw)                   # warm the jits
            compile_ms += s0.compile_time * 1e3
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                execute(pl, **kw)
                ts.append(time.perf_counter() - t0)
            out[f"t_{pname}_{label}_ms"] = min(ts) * 1e3
    out["compile_ms"] = compile_ms
    out["speedup_interpreted"] = (out["t_naive_interpreted_ms"]
                                  / out["t_opt_interpreted_ms"])
    out["speedup_compiled"] = (out["t_naive_compiled_ms"]
                               / out["t_opt_compiled_ms"])
    out["compile_win_opt"] = (out["t_opt_interpreted_ms"]
                              / out["t_opt_compiled_ms"])
    out["loop_win_opt"] = (out["t_opt_compiled_ms"]
                           / out["t_opt_compiled_loop_ms"])

    # --- plan-space explorer: the tuned winner ---------------------------
    from repro.core import winner_exec_kwargs
    tuned = plan(p, policy="auto", reps=reps)
    kw = winner_exec_kwargs(tuned)   # honors fuse_loops AND donate
    execute(tuned, **kw)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        execute(tuned, **kw)
        ts.append(time.perf_counter() - t0)
    out["t_auto_ms"] = min(ts) * 1e3
    out["auto_variant"] = tuned.meta["tuning"]["chosen"]
    out["auto_candidates"] = sum(
        1 for c in tuned.meta["tuning"]["candidates"] if c["valid"])
    chosen = tuned.predicted_cost()
    out["auto_predicted_ms"] = chosen["predicted_s"] * 1e3
    # persistent-cache + calibration outcome (ISSUE 5): a repeated run
    # answers from the tuning cache with zero measurements
    cache_info = tuned.tuning_cache_info()
    out["auto_cache_hit"] = cache_info["hit"]
    out["auto_measurements"] = cache_info["measurements"]
    cal = tuned.tuning_calibration() or {}
    out["auto_calibration_accepted"] = bool(cal.get("accepted"))
    return out


def main():
    r = run()
    print(f"{r['name']},{r['t_planned_ms'] * 1e3 / STEPS:.0f},"
          f"speedup={r['speedup']:.2f}x;sync_ms={r['t_sync_ms']:.0f};"
          f"planned_ms={r['t_planned_ms']:.0f}")
    e = run_plan_executor()
    extra = ";".join(f"{k}={v:.2f}" if isinstance(v, float) else f"{k}={v}"
                     for k, v in e.items() if k != "name")
    print(f"{e['name']},{e['t_opt_compiled_ms'] * 1e3:.0f},{extra}")
    return r


if __name__ == "__main__":
    main()
