"""Tuning-regression gate (ISSUE 5 satellite).

CI runs this after the ``--tune --quick`` smoke step.  It guards the
plan-space tuner's DETERMINISTIC surface — the cost model's predicted
ranking — against silent regressions:

1. Re-enumerates the gate programs (the ``directive_micro`` benchmark
   programs + the 3mm worked example + the flash-attention step with its
   kernel tile axis, at ``--quick`` sizes) with ``measure=False``,
   default hardware constants, and no cache, and compares the predicted
   winner label + predicted cost + valid-candidate count + enumerated
   kernel-variant count against ``tests/golden/tuning_baseline.json``.
2. Cross-checks ``tuning_report.json`` (the artifact the smoke step just
   wrote, ``--report PATH``): its predicted-rank-1 candidate per program
   must match the golden winner within the same tolerance.  The measured
   winner is reported but NOT gated — wall-clock noise on shared CI
   runners picks among near-equal candidates, whereas the predicted
   ordering is reproducible.

Exit status 1 on any regression.  Regenerate after an intentional
cost-model change (bump ``COST_MODEL_VERSION`` too) with:

    PYTHONPATH=src python benchmarks/check_tuning_baseline.py --update

``--update`` also regenerates ``tests/golden/calibration_3mm.json``, the
calibration round-trip fixture: real predicted-term rows from the gate
programs with measured times synthesized from ground-truth constants
that disagree with the defaults (so the default ranking is provably
imperfect and a correct least-squares fit provably repairs it).
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Dict, List

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent.parent / "tests" / "golden"
BASELINE_PATH = GOLDEN_DIR / "tuning_baseline.json"
CALIBRATION_PATH = GOLDEN_DIR / "calibration_3mm.json"

# the baseline is defined at the CI smoke sizes (directive_micro --quick)
QUICK_N, QUICK_ITERS = 256, 4

REL_TOL = 0.05   # predicted_s drift allowed (HLO flop counts move a
                 # little across jax versions; label changes never do)

# ground truth for the synthesized calibration fixture: slow link, fat
# per-dispatch overheads, and a device roofline (hbm_bw /
# peak_flops_bf16, machine balance 10 flop/byte) far from HW defaults —
# so dispatch-heavy candidates reorder vs. the default prediction AND
# the fixture has both compute-bound and memory-bound rows for the
# joint two-level fit to separate
_CAL_TRUE = {"pcie_bw": 4e9, "launch_overhead_s": 8e-4,
             "sync_overhead_s": 2e-4,
             "hbm_bw": 2e11, "peak_flops_bf16": 2e12}
_CAL_ROW_KEYS = ("label", "h2d_bytes", "d2h_bytes", "loads", "stores",
                 "syncs", "dispatches", "flops", "kernel_bytes",
                 "kernel_s", "predicted_s")


def _gate_programs() -> Dict[str, object]:
    import directive_micro as dm
    from repro.optim.offload import attention_step_program
    from repro.polybench import build_3mm
    saved = dm.N, dm.ITERS
    dm.N, dm.ITERS = QUICK_N, QUICK_ITERS
    try:
        progs = {
            "fig4_advancedload": dm._advancedload_prog(),
            "fig5_delegatestore": dm._delegatestore_prog(),
            "table2_3mm": build_3mm(n=QUICK_N)[0],
            "attn_step": attention_step_program(n_steps=1),
        }
    finally:
        dm.N, dm.ITERS = saved
    return progs


def _predicted_rank1(candidates: List[Dict]) -> Dict:
    return next(c for c in candidates if c["valid"] and c["rank"] == 1)


def compute_baseline() -> Dict[str, Dict]:
    """Deterministic per-program baseline: predicted winner under
    default constants, no measurement, no cache, no calibration."""
    from directive_micro import n_kernel_variants
    from repro.core import tune
    from repro.core.verify import verify_plan
    out = {}
    for name, prog in sorted(_gate_programs().items()):
        pl = tune(prog, backend="numpy", measure=False, cache=False,
                  use_calibration=False)
        valid = [c for c in pl.meta["tuning"]["candidates"] if c["valid"]]
        top = _predicted_rank1(valid)
        tuning = pl.meta["tuning"]
        out[name] = {
            "predicted_winner": top["label"],
            "predicted_s": top["predicted_s"],
            "n_valid": len(valid),
            "n_kernel_variants": n_kernel_variants(valid),
            # the multi-objective surface (ISSUE 10) is as deterministic
            # as the predicted ranking: the rank-1 candidate's modeled
            # joules and residency-walk peak, the per-objective winner
            # labels, and the Pareto point count are all gated
            "energy_j": top["energy_j"],
            "peak_bytes": top["peak_bytes"],
            "winners": dict(tuning["winners"]),
            "n_pareto": len(tuning["pareto"]),
            # the winning plan must pass the static verifier
            # (repro.core.verify) — a cost-model change that promotes
            # a racy/inconsistent candidate is a regression even if
            # its predicted cost looks great
            "verified": bool(verify_plan(pl).ok),
        }
    return out


def _build_calibration_rows() -> Dict:
    from repro.core import tune
    from repro.polybench import build
    from repro.roofline.analysis import HW, offload_cost_terms
    progs = dict(_gate_programs())
    progs["gemm"] = build("gemm", n=QUICK_N, iters=8)[0]
    progs["jacobi2d"] = build("jacobi2d", n=QUICK_N, iters=8)[0]
    hw_true = dict(HW)
    hw_true.update(_CAL_TRUE)
    rows = []
    for name, prog in sorted(progs.items()):
        pl = tune(prog, backend="numpy", measure=False, cache=False,
                  use_calibration=False)
        for c in pl.meta["tuning"]["candidates"]:
            if c["valid"] and c["alias_of"] is None:
                row = {k: c[k] for k in _CAL_ROW_KEYS}
                row["program"] = name
                row["measured_s"] = offload_cost_terms(
                    c["h2d_bytes"], c["d2h_bytes"], c["dispatches"],
                    c["syncs"], c["flops"], c["kernel_bytes"],
                    hw=hw_true)["predicted_s"]
                rows.append(row)
    return {"true_hw": _CAL_TRUE, "rows": rows,
            "note": "measured_s synthesized from true_hw via "
                    "offload_cost_terms over real predicted terms; "
                    "regenerate: PYTHONPATH=src python "
                    "benchmarks/check_tuning_baseline.py --update"}


def update() -> None:
    from repro.core import COST_MODEL_VERSION
    baseline = {
        "cost_model_version": COST_MODEL_VERSION,
        "params": {"N": QUICK_N, "ITERS": QUICK_ITERS},
        "rel_tol": REL_TOL,
        "programs": compute_baseline(),
    }
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    BASELINE_PATH.write_text(json.dumps(baseline, indent=2, sort_keys=True)
                             + "\n")
    CALIBRATION_PATH.write_text(
        json.dumps(_build_calibration_rows(), indent=2, sort_keys=True)
        + "\n")
    print(f"wrote {BASELINE_PATH}\nwrote {CALIBRATION_PATH}")


def check(report_path: str = None) -> List[str]:
    """Compare current predictions (and optionally a tuning_report.json)
    against the golden baseline; returns a list of regression messages
    (empty = pass)."""
    from repro.core import COST_MODEL_VERSION
    golden = json.loads(BASELINE_PATH.read_text())
    tol = golden.get("rel_tol", REL_TOL)
    problems = []
    if golden["cost_model_version"] != COST_MODEL_VERSION:
        problems.append(
            f"cost-model version drift: golden v{golden['cost_model_version']}"
            f" vs current v{COST_MODEL_VERSION} — regenerate the baseline "
            "(--update) alongside the version bump")
    current = compute_baseline()
    for name, want in sorted(golden["programs"].items()):
        got = current.get(name)
        if got is None:
            problems.append(f"{name}: gate program disappeared")
            continue
        if got["predicted_winner"] != want["predicted_winner"]:
            problems.append(
                f"{name}: predicted winner changed "
                f"{want['predicted_winner']} -> {got['predicted_winner']}")
        drift = abs(got["predicted_s"] - want["predicted_s"]) \
            / max(want["predicted_s"], 1e-30)
        if drift > tol:
            problems.append(
                f"{name}: predicted cost drifted {drift:.1%} "
                f"({want['predicted_s']:.3e}s -> {got['predicted_s']:.3e}s, "
                f"tol {tol:.0%})")
        if got["n_valid"] < want["n_valid"]:
            problems.append(
                f"{name}: valid candidates shrank "
                f"{want['n_valid']} -> {got['n_valid']}")
        if got["n_kernel_variants"] < want.get("n_kernel_variants", 1):
            problems.append(
                f"{name}: enumerated kernel variants shrank "
                f"{want['n_kernel_variants']} -> "
                f"{got['n_kernel_variants']} — the kernel tile axis "
                "stopped being explored")
        for col in ("energy_j", "peak_bytes"):
            if col not in want:
                continue          # pre-multi-objective golden
            drift = abs(got[col] - want[col]) / max(want[col], 1e-30)
            if drift > tol:
                problems.append(
                    f"{name}: {col} drifted {drift:.1%} "
                    f"({want[col]:.3e} -> {got[col]:.3e}, tol {tol:.0%})")
        for obj, label in sorted(want.get("winners", {}).items()):
            if got["winners"].get(obj) != label:
                problems.append(
                    f"{name}: {obj}-objective winner changed "
                    f"{label} -> {got['winners'].get(obj)}")
        if got.get("n_pareto", 0) < want.get("n_pareto", 0):
            problems.append(
                f"{name}: Pareto frontier shrank "
                f"{want['n_pareto']} -> {got['n_pareto']} points")
        if not got["verified"]:
            problems.append(
                f"{name}: tuned winner {got['predicted_winner']} no "
                "longer passes the static plan verifier "
                "(races / transfer consistency / donation safety)")
    if report_path:
        problems += _check_report(report_path, golden, tol)
    return problems


def _check_report(report_path: str, golden: Dict, tol: float) -> List[str]:
    """The CI artifact's predicted-rank-1 row must agree with the golden
    baseline (the report is produced with default pricing —
    ``bench_tuner`` passes ``use_calibration=False`` for exactly this)."""
    try:
        report = json.loads(pathlib.Path(report_path).read_text())
    except (OSError, ValueError) as e:
        return [f"tuning report {report_path} unreadable: {e}"]
    problems = []
    for name, want in sorted(golden["programs"].items()):
        tuning = report.get("programs", {}).get(name)
        if tuning is None:
            problems.append(f"{name}: missing from {report_path}")
            continue
        top = _predicted_rank1(tuning["candidates"])
        if top["label"] != want["predicted_winner"]:
            problems.append(
                f"{name}: report predicted winner {top['label']} != "
                f"golden {want['predicted_winner']}")
        drift = abs(top["predicted_s"] - want["predicted_s"]) \
            / max(want["predicted_s"], 1e-30)
        if drift > tol:
            problems.append(
                f"{name}: report predicted cost drifted {drift:.1%} "
                f"from golden (tol {tol:.0%})")
        chosen = next(c for c in tuning["candidates"]
                      if c["label"] == tuning["chosen"])
        if not chosen.get("measured_s"):
            problems.append(f"{name}: report winner was never measured")
    return problems


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if "--update" in args:
        update()
        return 0
    report = None
    if "--report" in args:
        report = args[args.index("--report") + 1]
    problems = check(report)
    if problems:
        print("TUNING REGRESSION:")
        for p in problems:
            print(f"  - {p}")
        print("(intentional change? regenerate with: PYTHONPATH=src "
              "python benchmarks/check_tuning_baseline.py --update)")
        return 1
    print(f"tuning baseline OK ({BASELINE_PATH.name}"
          + (f", report {report} consistent" if report else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
