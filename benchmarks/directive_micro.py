"""Figs. 4 & 5 analogues: what the two placement optimizations buy.

bench_advancedload (Fig. 4): a kernel inside a loop consumes a large
matrix written on the host BEFORE the loop.  Naive reloads it at every
callsite (4a); the planner hoists one async upload next to the last host
write (4b) — residency makes iterations transfer-free.

bench_delegatestore (Fig. 5): a kernel's output is host-read only once,
deep after other host work.  Naive downloads at kernel end (5a,
synchronous); the planner sinks the store next to the first host read
(5b), so the device result is fetched once and late (async dispatch keeps
the host busy meanwhile).

Each benchmark now reports BOTH execution modes: ``interp`` walks the
plan op-by-op through Python, ``compiled`` runs the jit-lowered fused
schedule (``repro.core.compile``).  The paper's effect is the opt-vs-naive
gap; the compiled columns show it survives (and sharpens) once Python
dispatch overhead is compiled away.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import Program, execute, naive_plan, plan

N = 1536
ITERS = 8
REPS = 3


def _advancedload_prog():
    rng = np.random.default_rng(0)
    p = Program("fig4")
    p.bind("W", rng.standard_normal((N, N)).astype(np.float32))
    p.bind("x", rng.standard_normal((N,)).astype(np.float32))
    with p.loop(ITERS):
        p.offload(lambda xp, W, x: {"x": xp.tanh(W @ x)},
                  reads=("W", "x"), writes=("x",), name="apply")
    p.host(lambda xp, x: {"out": x[:4]}, reads=("x",), writes=("out",),
           name="read")
    p.set_outputs("out")
    return p


def _delegatestore_prog():
    rng = np.random.default_rng(1)
    p = Program("fig5")
    p.bind("A", rng.standard_normal((N, N)).astype(np.float32))
    p.bind("h", rng.standard_normal((N,)).astype(np.float32))
    p.offload(lambda xp, A: {"C": A @ A.T}, reads=("A",), writes=("C",),
              name="produce")
    with p.loop(ITERS):
        p.host(lambda xp, h: {"h": xp.tanh(h * 1.01)}, reads=("h",),
               writes=("h",), name="hostwork")
    p.host(lambda xp, C, h: {"out": C[:2, :2] + h[:2]},
           reads=("C", "h"), writes=("out",), name="readC")
    p.set_outputs("out")
    return p


def _time(fn):
    fn()
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _grid(p) -> Dict[str, float]:
    """min wall time for {naive, opt} x {interpreted, compiled}."""
    plans = {"naive": naive_plan(p), "opt": plan(p)}
    out = {}
    for pname, pl in plans.items():
        for mode in ("interpreted", "compiled"):
            out[f"t_{pname}_{mode}_ms"] = _time(
                lambda pl=pl, mode=mode: execute(pl, mode=mode)) * 1e3
    return out


def bench_advancedload() -> Dict:
    p = _advancedload_prog()
    g = _grid(p)
    _, s_nv = execute(naive_plan(p))
    _, s_opt = execute(plan(p), mode="compiled")
    return {
        "name": "fig4_advancedload",
        "t_naive_ms": g["t_naive_interpreted_ms"],
        "t_opt_ms": g["t_opt_interpreted_ms"],
        "t_naive_compiled_ms": g["t_naive_compiled_ms"],
        "t_opt_compiled_ms": g["t_opt_compiled_ms"],
        "h2d_naive": s_nv.h2d_transfers, "h2d_opt": s_opt.h2d_transfers,
        "h2d_bytes_naive": s_nv.h2d_bytes, "h2d_bytes_opt": s_opt.h2d_bytes,
        "fused_launches_opt": s_opt.fused_launches,
        "speedup": g["t_naive_interpreted_ms"] / g["t_opt_interpreted_ms"],
        "speedup_compiled": (g["t_naive_compiled_ms"]
                             / g["t_opt_compiled_ms"]),
    }


def bench_delegatestore() -> Dict:
    p = _delegatestore_prog()
    g = _grid(p)
    _, s_nv = execute(naive_plan(p))
    _, s_opt = execute(plan(p), mode="compiled")
    return {
        "name": "fig5_delegatestore",
        "t_naive_ms": g["t_naive_interpreted_ms"],
        "t_opt_ms": g["t_opt_interpreted_ms"],
        "t_naive_compiled_ms": g["t_naive_compiled_ms"],
        "t_opt_compiled_ms": g["t_opt_compiled_ms"],
        "d2h_naive": s_nv.d2h_transfers, "d2h_opt": s_opt.d2h_transfers,
        "fused_launches_opt": s_opt.fused_launches,
        "speedup": g["t_naive_interpreted_ms"] / g["t_opt_interpreted_ms"],
        "speedup_compiled": (g["t_naive_compiled_ms"]
                             / g["t_opt_compiled_ms"]),
    }


def main():
    results = []
    for bench in (bench_advancedload, bench_delegatestore):
        r = bench()
        results.append(r)
        extra = ";".join(f"{k}={v}" for k, v in r.items()
                         if k not in ("name", "t_opt_ms"))
        print(f"{r['name']},{r['t_opt_ms'] * 1e3:.0f},{extra}")
    return results


if __name__ == "__main__":
    main()
