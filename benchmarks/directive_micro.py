"""Figs. 4 & 5 analogues: what the two placement optimizations buy.

bench_advancedload (Fig. 4): a kernel inside a loop consumes a large
matrix written on the host BEFORE the loop.  Naive reloads it at every
callsite (4a); the planner hoists one async upload next to the last host
write (4b) — residency makes iterations transfer-free.

bench_delegatestore (Fig. 5): a kernel's output is host-read only once,
deep after other host work.  Naive downloads at kernel end (5a,
synchronous); the planner sinks the store next to the first host read
(5b), so the device result is fetched once and late (async dispatch keeps
the host busy meanwhile).

Each benchmark reports THREE execution modes: ``interp`` walks the plan
op-by-op through Python, ``compiled`` runs the jit-lowered fused
schedule with per-iteration segment dispatch, and ``compiled_loop``
additionally rolls pure-device loops whole into one ``lax.fori_loop``
launch (``execute``'s default compiled behaviour).  The paper's effect
is the opt-vs-naive gap; the compiled columns show it survives (and
sharpens) once Python dispatch overhead is compiled away.

All wall times are steady-state: plans are lowered and jits warmed
before timing, and one-time lowering cost is reported separately as
``compile_ms`` (``ExecStats.compile_time``), never folded into the
timed columns.

``--tune`` additionally runs the plan-space explorer
(``plan(p, policy="auto")``) on each benchmark program plus the 3mm
worked example and the flash-attention step (the kernel-axis program:
its tile variants are enumerated and measured), prints the winner per
program, and writes the full ranked predicted-vs-measured tables to
``tuning_report.json`` (the CI artifact) plus a dated snapshot
``BENCH_<YYYYMMDD>.json`` at the repo root so successive runs can be
diffed.  ``--quick`` shrinks sizes for CI smoke runs.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict

import numpy as np

from repro.core import Program, execute, naive_plan, plan

N = 1536
ITERS = 8
REPS = 3

# (column label, execute kwargs)
MODES = (
    ("interpreted", dict(mode="interpreted")),
    ("compiled", dict(mode="compiled", fuse_loops=False)),
    ("compiled_loop", dict(mode="compiled", fuse_loops=True)),
)


def _advancedload_prog():
    rng = np.random.default_rng(0)
    p = Program("fig4")
    p.bind("W", rng.standard_normal((N, N)).astype(np.float32))
    p.bind("x", rng.standard_normal((N,)).astype(np.float32))
    with p.loop(ITERS):
        p.offload(lambda xp, W, x: {"x": xp.tanh(W @ x)},
                  reads=("W", "x"), writes=("x",), name="apply")
    p.host(lambda xp, x: {"out": x[:4]}, reads=("x",), writes=("out",),
           name="read")
    p.set_outputs("out")
    return p


def _delegatestore_prog():
    rng = np.random.default_rng(1)
    p = Program("fig5")
    p.bind("A", rng.standard_normal((N, N)).astype(np.float32))
    p.bind("h", rng.standard_normal((N,)).astype(np.float32))
    p.offload(lambda xp, A: {"C": A @ A.T}, reads=("A",), writes=("C",),
              name="produce")
    with p.loop(ITERS):
        p.host(lambda xp, h: {"h": xp.tanh(h * 1.01)}, reads=("h",),
               writes=("h",), name="hostwork")
    p.host(lambda xp, C, h: {"out": C[:2, :2] + h[:2]},
           reads=("C", "h"), writes=("out",), name="readC")
    p.set_outputs("out")
    return p


def _time(fn):
    fn()
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _grid(p) -> Dict[str, float]:
    """Steady-state min wall time for {naive, opt} x MODES, plus the
    one-time lowering cost per plan (compile_ms)."""
    plans = {"naive": naive_plan(p), "opt": plan(p)}
    out = {}
    for pname, pl in plans.items():
        compile_ms = 0.0
        for label, kw in MODES:
            # warm inside _time; first call's stats carry compile_time
            _, s0 = execute(pl, **kw)
            compile_ms += s0.compile_time * 1e3
            out[f"t_{pname}_{label}_ms"] = _time(
                lambda pl=pl, kw=kw: execute(pl, **kw)) * 1e3
        out[f"compile_{pname}_ms"] = compile_ms
    return out


def bench_advancedload() -> Dict:
    p = _advancedload_prog()
    g = _grid(p)
    _, s_nv = execute(naive_plan(p))
    _, s_opt = execute(plan(p), mode="compiled")
    return {
        "name": "fig4_advancedload",
        "t_naive_ms": g["t_naive_interpreted_ms"],
        "t_opt_ms": g["t_opt_interpreted_ms"],
        "t_naive_compiled_ms": g["t_naive_compiled_ms"],
        "t_opt_compiled_ms": g["t_opt_compiled_ms"],
        "t_naive_compiled_loop_ms": g["t_naive_compiled_loop_ms"],
        "t_opt_compiled_loop_ms": g["t_opt_compiled_loop_ms"],
        "compile_opt_ms": g["compile_opt_ms"],
        "h2d_naive": s_nv.h2d_transfers, "h2d_opt": s_opt.h2d_transfers,
        "h2d_bytes_naive": s_nv.h2d_bytes, "h2d_bytes_opt": s_opt.h2d_bytes,
        "fused_launches_opt": s_opt.fused_launches,
        "speedup": g["t_naive_interpreted_ms"] / g["t_opt_interpreted_ms"],
        "speedup_compiled": (g["t_naive_compiled_ms"]
                             / g["t_opt_compiled_ms"]),
        "speedup_loop": (g["t_opt_compiled_ms"]
                         / g["t_opt_compiled_loop_ms"]),
    }


def bench_delegatestore() -> Dict:
    p = _delegatestore_prog()
    g = _grid(p)
    _, s_nv = execute(naive_plan(p))
    _, s_opt = execute(plan(p), mode="compiled")
    return {
        "name": "fig5_delegatestore",
        "t_naive_ms": g["t_naive_interpreted_ms"],
        "t_opt_ms": g["t_opt_interpreted_ms"],
        "t_naive_compiled_ms": g["t_naive_compiled_ms"],
        "t_opt_compiled_ms": g["t_opt_compiled_ms"],
        "t_naive_compiled_loop_ms": g["t_naive_compiled_loop_ms"],
        "t_opt_compiled_loop_ms": g["t_opt_compiled_loop_ms"],
        "compile_opt_ms": g["compile_opt_ms"],
        "d2h_naive": s_nv.d2h_transfers, "d2h_opt": s_opt.d2h_transfers,
        "fused_launches_opt": s_opt.fused_launches,
        "speedup": g["t_naive_interpreted_ms"] / g["t_opt_interpreted_ms"],
        "speedup_compiled": (g["t_naive_compiled_ms"]
                             / g["t_opt_compiled_ms"]),
        "speedup_loop": (g["t_opt_compiled_ms"]
                         / g["t_opt_compiled_loop_ms"]),
    }


def bench_tuner(out_path: str = "tuning_report.json") -> Dict:
    """Plan-space exploration over the benchmark programs + 3mm: the
    winner per program and the full ranked candidate tables, persisted
    as the CI ``tuning_report.json`` artifact.

    Predictions are priced with the DEFAULT hardware constants
    (``use_calibration=False``) so the report's predicted ranking is
    machine-independent — the tuning-regression gate
    (``check_tuning_baseline.py``) diffs it against the checked-in
    baseline.  The persistent cache stays ON: a repeated CI run restores
    ``.tunecache`` (actions/cache) and answers without re-measuring —
    ``cache_hit``/``measurements`` per program record which happened.
    The measured calibration is still fitted and reported (the 3mm
    table's before/after rank correlations land in the artifact)."""
    from repro.core import COST_MODEL_VERSION
    from repro.optim.offload import attention_step_program
    from repro.polybench import build_3mm
    p3, _ = build_3mm(n=min(N, 256))
    programs = {
        "fig4_advancedload": _advancedload_prog(),
        "fig5_delegatestore": _delegatestore_prog(),
        "table2_3mm": p3,
        "attn_step": attention_step_program(n_steps=1),
    }
    # the kernel program's interesting axis is the tile grid; pin the
    # plan axes so the smoke run measures kernel VARIANTS (interpret-mode
    # Pallas on CPU CI is too slow for the full 48-config cross product)
    grid_kw = {"attn_step": dict(policies=("optimized",), streams=(1,),
                                 fuse=(True,), donate=(False,))}
    report: Dict[str, Dict] = {"params": {"N": N, "ITERS": ITERS},
                               "cost_model_version": COST_MODEL_VERSION,
                               "programs": {}, "summary": {}}
    rows = {}
    for name, prog in sorted(programs.items()):
        pl = plan(prog, policy="auto", reps=max(1, REPS - 1),
                  use_calibration=False, **grid_kw.get(name, {}))
        tuning = pl.meta["tuning"]
        cache_info = pl.meta["tuning_cache"]
        chosen = pl.predicted_cost()
        cal = tuning.get("calibration") or {}
        report["programs"][name] = tuning
        # roofline drift: measured-vs-predicted kernel_s residual across
        # every measured variant (ISSUE 9 satellite — per-candidate
        # residuals live in the candidate records themselves)
        resid = [abs(c.get("kernel_residual_s") or 0.0)
                 for c in tuning["candidates"]
                 if c.get("measured_kernel_s") is not None]
        rows[name] = {
            "chosen": tuning["chosen"],
            "max_kernel_residual_ms": max(resid, default=0.0) * 1e3,
            "n_candidates": sum(1 for c in tuning["candidates"]
                                if c["valid"]),
            "n_kernel_variants": n_kernel_variants(tuning["candidates"]),
            "predicted_ms": chosen["predicted_s"] * 1e3,
            "measured_ms": (chosen["measured_s"] or 0.0) * 1e3,
            # multi-objective columns (ISSUE 10): the chosen plan's
            # modeled energy + residency-walk peak, the per-objective
            # winner labels, the frontier size, and the cold-start
            # predictor verdict
            "energy_mj": (chosen.get("energy_j") or 0.0) * 1e3,
            "peak_mb": (chosen.get("peak_bytes") or 0.0) / 1e6,
            "n_pareto": len(tuning.get("pareto") or ()),
            "winner_time": (tuning.get("winners") or {}).get("time"),
            "winner_energy": (tuning.get("winners") or {}).get("energy"),
            "winner_memory": (tuning.get("winners") or {}).get("memory"),
            "predictor_accepted": bool(
                (tuning.get("predictor") or {}).get("accepted")),
            "cache_hit": cache_info["hit"],
            "measurements": cache_info["measurements"],
            "calibration_accepted": bool(cal.get("accepted")),
        }
        report["summary"][name] = rows[name]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True, default=float)
    return {"name": "plan_tuner", "report_path": out_path, "rows": rows}


def n_kernel_variants(candidates) -> int:
    """Distinct kernel tile-variant assignments enumerated in a tuning
    table (1 for kernel-free programs: the single empty assignment)."""
    return len({json.dumps(c["config"].get("kernel_variants") or [])
                for c in candidates if c["valid"]})


def write_bench_snapshot(rows: Dict, path: str = None) -> str:
    """Dated tuning summary at the repo root (``BENCH_<YYYYMMDD>.json``)
    so successive runs of ``--tune`` can be diffed; CI uploads it as an
    artifact."""
    from repro.core import COST_MODEL_VERSION
    if path is None:
        path = f"BENCH_{time.strftime('%Y%m%d')}.json"
    snap = {
        "date": time.strftime("%Y-%m-%d"),
        "cost_model_version": COST_MODEL_VERSION,
        "params": {"N": N, "ITERS": ITERS, "REPS": REPS},
        "programs": rows,
    }
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=float)
    return path


def main(argv=None):
    global N, ITERS, REPS
    args = list(sys.argv[1:] if argv is None else argv)
    if "--quick" in args:
        N, ITERS, REPS = 256, 4, 1   # CI smoke: exercise every column fast
    if "--tune" in args:
        r = bench_tuner()
        for name, row in sorted(r["rows"].items()):
            extra = ";".join(f"{k}={v if not isinstance(v, float) else round(v, 3)}"
                             for k, v in row.items())
            print(f"tune_{name},{row['measured_ms'] * 1e3:.0f},{extra}")
        print(f"tuning report written to {r['report_path']}")
        snap = write_bench_snapshot(r["rows"])
        print(f"bench snapshot written to {snap}")
        return [r]
    results = []
    for bench in (bench_advancedload, bench_delegatestore):
        r = bench()
        results.append(r)
        extra = ";".join(f"{k}={v}" for k, v in r.items()
                         if k not in ("name", "t_opt_ms"))
        print(f"{r['name']},{r['t_opt_ms'] * 1e3:.0f},{extra}")
    return results


if __name__ == "__main__":
    main()
