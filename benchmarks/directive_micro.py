"""Figs. 4 & 5 analogues: what the two placement optimizations buy.

bench_advancedload (Fig. 4): a kernel inside a loop consumes a large
matrix written on the host BEFORE the loop.  Naive reloads it at every
callsite (4a); the planner hoists one async upload next to the last host
write (4b) — residency makes iterations transfer-free.

bench_delegatestore (Fig. 5): a kernel's output is host-read only once,
deep after other host work.  Naive downloads at kernel end (5a,
synchronous); the planner sinks the store next to the first host read
(5b), so the device result is fetched once and late (async dispatch keeps
the host busy meanwhile).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import Program, execute, naive_plan, plan

N = 1536
ITERS = 8
REPS = 3


def _advancedload_prog():
    rng = np.random.default_rng(0)
    p = Program("fig4")
    p.bind("W", rng.standard_normal((N, N)).astype(np.float32))
    p.bind("x", rng.standard_normal((N,)).astype(np.float32))
    with p.loop(ITERS):
        p.offload(lambda xp, W, x: {"x": xp.tanh(W @ x)},
                  reads=("W", "x"), writes=("x",), name="apply")
    p.host(lambda xp, x: {"out": x[:4]}, reads=("x",), writes=("out",),
           name="read")
    p.set_outputs("out")
    return p


def _delegatestore_prog():
    rng = np.random.default_rng(1)
    p = Program("fig5")
    p.bind("A", rng.standard_normal((N, N)).astype(np.float32))
    p.bind("h", rng.standard_normal((N,)).astype(np.float32))
    p.offload(lambda xp, A: {"C": A @ A.T}, reads=("A",), writes=("C",),
              name="produce")
    with p.loop(ITERS):
        p.host(lambda xp, h: {"h": xp.tanh(h * 1.01)}, reads=("h",),
               writes=("h",), name="hostwork")
    p.host(lambda xp, C, h: {"out": C[:2, :2] + h[:2]},
           reads=("C", "h"), writes=("out",), name="readC")
    p.set_outputs("out")
    return p


def _time(fn):
    fn()
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench_advancedload() -> Dict:
    p = _advancedload_prog()
    t_nv = _time(lambda: execute(naive_plan(p)))
    t_opt = _time(lambda: execute(plan(p)))
    _, s_nv = execute(naive_plan(p))
    _, s_opt = execute(plan(p))
    return {
        "name": "fig4_advancedload",
        "t_naive_ms": t_nv * 1e3, "t_opt_ms": t_opt * 1e3,
        "h2d_naive": s_nv.h2d_transfers, "h2d_opt": s_opt.h2d_transfers,
        "h2d_bytes_naive": s_nv.h2d_bytes, "h2d_bytes_opt": s_opt.h2d_bytes,
        "speedup": t_nv / t_opt,
    }


def bench_delegatestore() -> Dict:
    p = _delegatestore_prog()
    t_nv = _time(lambda: execute(naive_plan(p)))
    t_opt = _time(lambda: execute(plan(p)))
    _, s_nv = execute(naive_plan(p))
    _, s_opt = execute(plan(p))
    return {
        "name": "fig5_delegatestore",
        "t_naive_ms": t_nv * 1e3, "t_opt_ms": t_opt * 1e3,
        "d2h_naive": s_nv.d2h_transfers, "d2h_opt": s_opt.d2h_transfers,
        "sync_wait_naive_ms": 0.0,
        "speedup": t_nv / t_opt,
    }


def main():
    for bench in (bench_advancedload, bench_delegatestore):
        r = bench()
        extra = ";".join(f"{k}={v}" for k, v in r.items()
                         if k not in ("name", "t_opt_ms"))
        print(f"{r['name']},{r['t_opt_ms'] * 1e3:.0f},{extra}")
    return None


if __name__ == "__main__":
    main()
