"""Render the §Roofline table from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--variant baseline]
"""
from __future__ import annotations

import argparse
import glob
import json
from typing import List


def load(variant: str = "baseline", outdir: str = "artifacts/dryrun"):
    rows = []
    for f in sorted(glob.glob(f"{outdir}/*__{variant}.json")):
        rows.append(json.load(open(f)))
    return rows


def table(rows: List[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS | useful | roofline | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | — | SKIP: {r['reason'][:60]} |")
            continue
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['bottleneck'].replace('_s', '')} | "
            f"{ro['model_flops']:.3g} | {ro['useful_ratio']:.2f} | "
            f"{ro['roofline_fraction']:.3f} | "
            f"temp {r['memory']['temp_bytes'] / 1e9:.1f}GB |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--outdir", default="artifacts/dryrun")
    args = ap.parse_args()
    rows = load(args.variant, args.outdir)
    print(table(rows, args.mesh))


if __name__ == "__main__":
    main()
