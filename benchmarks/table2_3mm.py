"""Table 2 analogue: the generated 'source' for 3MM + its schedule stats.

Prints the HMPP-style emission (group/mapbyname/advancedload/async
callsites/noupdate/synchronize/delegatedstore/release — the same directive
structure as the paper's generated listing) and the measured transfer
schedule vs the naive policy.
"""
from __future__ import annotations

from repro.core import emit, execute, naive_plan, plan, transfer_summary
from repro.polybench import build


def run(n: int = 512, show_source: bool = True):
    p, _ = build("3mm", n=n)
    opt = plan(p)
    if show_source:
        print(emit(opt))
        print()
    execute(opt)                    # warm the jit caches
    execute(naive_plan(p))
    _, s_opt = execute(opt)
    _, s_nv = execute(naive_plan(p))
    summary = transfer_summary(opt)
    row = {
        "loads_opt": s_opt.h2d_transfers, "loads_naive": s_nv.h2d_transfers,
        "stores_opt": s_opt.d2h_transfers,
        "stores_naive": s_nv.d2h_transfers,
        "noupdate_args": summary["noupdate_args"],
        "bytes_opt": s_opt.h2d_bytes + s_opt.d2h_bytes,
        "bytes_naive": s_nv.h2d_bytes + s_nv.d2h_bytes,
        "wall_opt_ms": s_opt.wall_time * 1e3,
        "wall_naive_ms": s_nv.wall_time * 1e3,
    }
    return row


def main():
    row = run(show_source=True)
    extra = ";".join(f"{k}={v if not isinstance(v, float) else round(v,2)}"
                     for k, v in row.items() if k != "wall_opt_ms")
    print(f"table2_3mm,{row['wall_opt_ms'] * 1e3:.0f},{extra}")
    return row


if __name__ == "__main__":
    main()
