"""Fig. 6 analogue: Polybench speedups.

Paper axes: OMP2HMPP-generated vs sequential / OpenMP / hand-CUDA.
Container axes (CPU device): per problem we time
    seq       — pure-host numpy execution (the paper's 'sequential'),
    naive     — device offload, transfers at every callsite (Figs. 4a/5a),
    omp2hmpp  — the planner's optimized schedule (this paper's system),
    hand      — ideal hand-tuned bound: inputs pre-resident, zero
                transfers (the paper's 'hand-coded' reference point).
Derived columns: speedups vs seq and transfer bytes saved vs naive.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import execute, naive_plan, plan, run_host_oracle
from repro.core.backend import _jitted_block as _jitted
from repro.polybench import PROBLEMS, build

SIZES = {
    "2mm": dict(n=512), "3mm": dict(n=512), "gemm": dict(n=512, iters=4),
    "atax": dict(n=2048), "bicg": dict(n=2048), "mvt": dict(n=2048),
    "gesummv": dict(n=1536), "syrk": dict(n=512, iters=2),
    "covariance": dict(n=768), "jacobi2d": dict(n=768, iters=10),
}
REPS = 3


def _time(fn, reps=REPS):
    fn()                     # warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_hand(p, inputs):
    """Ideal bound: every offload block jitted, all arrays device-resident,
    one final host fetch."""
    import jax.numpy as jnp

    def run():
        env = {k: jnp.asarray(v) for k, v in inputs.items()}

        def exec_blocks(blocks, path):
            i = 0
            while i < len(blocks):
                blk = blocks[i]
                rel = blk.loop_path[len(path):]
                if not rel:
                    fn = _jitted(blk.fn, tuple(blk.reads),
                                 tuple(blk.writes))
                    outs = fn(*[env[v] for v in blk.reads])
                    for w, val in zip(blk.writes, outs):
                        env[w] = val
                    i += 1
                else:
                    lid = rel[0]
                    j = i
                    while j < len(blocks) and \
                            len(blocks[j].loop_path) > len(path) and \
                            blocks[j].loop_path[len(path)] == lid:
                        j += 1
                    for _ in range(p.loops[lid].n_iters):
                        exec_blocks(blocks[i:j], path + (lid,))
                    i = j
        exec_blocks(p.blocks, ())
        for name in p.outputs:
            np.asarray(env[name])
    return _time(run)


def run_suite() -> List[Dict]:
    rows = []
    for name in sorted(PROBLEMS):
        p, inputs = build(name, **SIZES[name])
        opt_plan, nv_plan = plan(p), naive_plan(p)

        t_seq = _time(lambda: run_host_oracle(p))
        t_nv = _time(lambda: execute(nv_plan))
        t_opt = _time(lambda: execute(opt_plan))
        t_hand = _time_hand(p, inputs)
        _, s_opt = execute(opt_plan)
        _, s_nv = execute(nv_plan)

        rows.append({
            "problem": name,
            "t_seq_ms": t_seq * 1e3,
            "t_naive_ms": t_nv * 1e3,
            "t_omp2hmpp_ms": t_opt * 1e3,
            "t_hand_ms": t_hand * 1e3,
            "speedup_vs_seq": t_seq / t_opt,
            "speedup_vs_naive": t_nv / t_opt,
            "hand_vs_omp2hmpp": t_opt / t_hand,
            "bytes_saved_vs_naive": (s_nv.h2d_bytes + s_nv.d2h_bytes
                                     - s_opt.h2d_bytes - s_opt.d2h_bytes),
            "transfers_opt": s_opt.h2d_transfers + s_opt.d2h_transfers,
            "transfers_naive": s_nv.h2d_transfers + s_nv.d2h_transfers,
        })
    return rows


def main():
    rows = run_suite()
    for r in rows:
        print(f"fig6_{r['problem']},{r['t_omp2hmpp_ms'] * 1e3:.0f},"
              f"speedup_seq={r['speedup_vs_seq']:.2f}x;"
              f"speedup_naive={r['speedup_vs_naive']:.2f}x;"
              f"hand_gap={r['hand_vs_omp2hmpp']:.2f}x;"
              f"bytes_saved={r['bytes_saved_vs_naive']}")
    return rows


if __name__ == "__main__":
    main()
