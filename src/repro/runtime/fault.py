"""Fault tolerance & straggler mitigation for 1000+-node runs.

This container has one process, so the *mechanisms* are implemented and
unit-tested against simulated workers (threads); the coordinator protocol
below is exactly what runs per-host on a real pod (see DESIGN.md §4):

  * Heartbeat      — every host ticks; the coordinator declares a host dead
                     after ``timeout`` missed ticks.
  * StepWatchdog   — per-step wall-time tracker; hosts slower than
                     ``factor`` × rolling-median are flagged stragglers
                     (on real pods: demote to spare, re-shard, restart from
                     the last checkpoint — the checkpoint format is
                     mesh-agnostic precisely so the survivor set can differ).
  * ElasticController — decides the restart mesh from the live-host set
                     (largest (pod, data, model) grid that divides the
                     survivors) and hands train.py the re-mesh parameters.
  * run_with_retries — in-process supervisor: restarts the train loop from
                     the latest checkpoint on (injected) failures; the
                     restart-equivalence test proves bitwise continuation.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["Heartbeat", "StepWatchdog", "ElasticController",
           "run_with_retries", "FaultInjector"]


class Heartbeat:
    def __init__(self, timeout: float = 5.0):
        self.timeout = timeout
        self._last: Dict[str, float] = {}
        self._lock = threading.Lock()

    def tick(self, host: str, now: Optional[float] = None) -> None:
        with self._lock:
            self._last[host] = now if now is not None else time.monotonic()

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        with self._lock:
            return sorted(h for h, t in self._last.items()
                          if now - t > self.timeout)

    def live_hosts(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        with self._lock:
            return sorted(h for h, t in self._last.items()
                          if now - t <= self.timeout)


class StepWatchdog:
    """Flags hosts whose step time exceeds factor × rolling median."""

    def __init__(self, factor: float = 2.0, window: int = 16):
        self.factor = factor
        self.window = window
        self._times: Dict[str, List[float]] = {}

    def record(self, host: str, step_time: float) -> None:
        self._times.setdefault(host, []).append(step_time)
        self._times[host] = self._times[host][-self.window:]

    def stragglers(self) -> List[str]:
        latest = {h: ts[-1] for h, ts in self._times.items() if ts}
        if len(latest) < 2:
            return []
        med = statistics.median(latest.values())
        return sorted(h for h, t in latest.items()
                      if t > self.factor * med)


@dataclasses.dataclass
class ElasticDecision:
    n_hosts: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]


class ElasticController:
    """Pick the restart mesh for a survivor set.  Keeps the model axis fixed
    (TP degree is a model property) and shrinks data/pod parallelism to the
    largest size the survivors support — checkpoints are mesh-agnostic, so
    restore works unchanged."""

    def __init__(self, chips_per_host: int = 4, model_axis: int = 16):
        self.chips_per_host = chips_per_host
        self.model_axis = model_axis

    def decide(self, n_live_hosts: int) -> ElasticDecision:
        chips = n_live_hosts * self.chips_per_host
        model = self.model_axis
        if chips < model:
            raise RuntimeError(
                f"{chips} chips cannot host a {model}-way model axis")
        data = chips // model
        # largest power-of-two data axis for even sharding
        d = 1
        while d * 2 <= data:
            d *= 2
        return ElasticDecision(n_hosts=n_live_hosts,
                               mesh_shape=(d, model),
                               mesh_axes=("data", "model"))


class FaultInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at_steps: Tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.failures = 0

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected failure at step {step}")


def run_with_retries(train_fn: Callable[[Optional[int]], int],
                     max_restarts: int = 3) -> Tuple[int, int]:
    """Supervise ``train_fn(resume_step) -> final_step``; on failure,
    restart from the latest checkpoint (train_fn reads it itself).
    Returns (final_step, n_restarts)."""
    restarts = 0
    while True:
        try:
            return train_fn(None), restarts
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
