"""Runtime fault tolerance: heartbeat, straggler watchdog, elastic restart."""
from .fault import (ElasticController, FaultInjector, Heartbeat,
                    StepWatchdog, run_with_retries)
__all__ = ["ElasticController", "FaultInjector", "Heartbeat",
           "StepWatchdog", "run_with_retries"]
