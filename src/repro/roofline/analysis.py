"""Roofline analysis from the compiled dry-run artifact.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so a scanned
48-layer model under-reports FLOPs ~50×.  This module parses the compiled
(post-SPMD, per-device) HLO text into its computation graph, recovers every
while loop's trip count from its condition computation, and propagates
multipliers through while/call/fusion/conditional edges.  With that:

  * collective bytes  — result-shape bytes of every all-reduce/all-gather/
    reduce-scatter/all-to-all/collective-permute × its loop multiplier
    (exact, since we count shapes ourselves);
  * HLO dot FLOPs     — 2 × result_elems × contraction_size for every
    dot/convolution × multiplier (covers ≈all model FLOPs; elementwise ops
    excluded, documented);
  * memory traffic    — an analytic HBM model (params/grads/optimizer/
    activations incl. remat recompute, or KV-cache reads for decode),
    because fusion-internal traffic is not recoverable from HLO text.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "CALIBRATABLE", "ENERGY_TERMS", "PREDICTOR_FEATURES",
           "parse_hlo", "collective_bytes",
           "dot_flops", "analytic_model_flops", "analytic_hbm_bytes",
           "roofline_terms", "offload_cost_terms", "kernel_roofline_terms",
           "fit_offload_constants", "rank_correlation",
           "candidate_features", "fit_candidate_predictor",
           "predict_candidate_s"]

HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    # host<->device interconnect + dispatch constants for the offload
    # planner's plan-space cost model (repro.core.tuner): effective
    # PCIe-class link for advancedload/delegatedstore traffic, and the
    # per-dispatch/per-sync host overheads a fused launch amortizes.
    "pcie_bw": 16e9,             # bytes/s host<->device
    "launch_overhead_s": 5e-6,   # per physical dispatch
    "sync_overhead_s": 2e-6,     # per wait point
    # per-byte / per-flop joule constants for the tuner's energy
    # objective (ISSUE 10, after the OMP2HMPP sequel's energy-performance
    # exploration): link energy dominates per byte moved over PCIe, HBM
    # access sits around single-digit pJ/byte, ICI between the two, and
    # an MXU flop costs a fraction of a pJ at bf16.  Calibratable via
    # ``hw=`` overrides like the time constants (there is no power meter
    # in the loop, so they are not part of the least-squares time fit).
    "pcie_j_per_byte": 2.0e-10,
    "hbm_j_per_byte": 7.0e-12,
    "ici_j_per_byte": 2.5e-11,
    "flop_j": 1.5e-13,
}

# the energy-model constants (a documented subset of HW; override via
# ``hw=`` to recalibrate for a different part)
ENERGY_TERMS = ("pcie_j_per_byte", "hbm_j_per_byte", "ici_j_per_byte",
                "flop_j")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->")


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, List[str]]
    entry: str
    multipliers: Dict[str, float]


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo(hlo: str) -> HloModule:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{"):
                m = _HEADER_RE.match(stripped)
                if m:
                    cur = m.group(1).lstrip("%")
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        entry = cur
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)

    # while edges: parent -> (body, trip);  call edges: parent -> callee ×1
    trip_of_cond: Dict[str, int] = {}
    while_edges: List[Tuple[str, str, str]] = []   # (parent, cond, body)
    call_edges: List[Tuple[str, str]] = []
    for name, lines in comps.items():
        for ln in lines:
            mw = re.search(
                r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                ln)
            if mw:
                while_edges.append((name, mw.group(1), mw.group(2)))
                continue
            for mc in re.finditer(r"(?:calls|to_apply|branch_computations)="
                                  r"\{?%?([\w.\-]+(?:, ?%?[\w.\-]+)*)\}?",
                                  ln):
                for callee in re.split(r",\s*%?", mc.group(1)):
                    call_edges.append((name, callee))

    for parent, cond, body in while_edges:
        consts = []
        for ln in comps.get(cond, ()):
            consts += [int(c) for c in re.findall(r"constant\((\d+)\)", ln)]
        trip_of_cond[body] = max(consts) if consts else 1

    # propagate multipliers from entry
    mult: Dict[str, float] = {}
    if entry is None:
        entry = next(iter(comps))
    stack = [(entry, 1.0)]
    children: Dict[str, List[Tuple[str, float]]] = {}
    for parent, cond, body in while_edges:
        children.setdefault(parent, []).append(
            (body, float(trip_of_cond.get(body, 1))))
        children.setdefault(parent, []).append((cond, 1.0))
    for parent, callee in call_edges:
        children.setdefault(parent, []).append((callee, 1.0))
    seen = set()
    while stack:
        name, m = stack.pop()
        if m > mult.get(name, 0.0):
            mult[name] = m
        key = (name, m)
        if key in seen:
            continue
        seen.add(key)
        for child, factor in children.get(name, ()):
            if child in comps:
                stack.append((child, m * factor))
    return HloModule(computations=comps, entry=entry, multipliers=mult)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(mod: HloModule) -> Dict[str, Dict[str, float]]:
    """Per-type collective traffic in RING-VOLUME bytes (the wire cost a
    bidirectional-ring algorithm moves per participant):

        all-reduce        2·(n−1)/n · tensor           (result printed = tensor)
        all-gather        (n−1)/n  · gathered          (result = gathered)
        reduce-scatter    (n−1)/n  · pre-reduce        (result = shard → ×n)
        all-to-all        (n−1)/n  · tensor
        collective-permute  1      · tensor

    n is parsed from ``replica_groups=[g,n]<=[...]``; ``bytes_result`` keeps
    the raw result-shape accounting for reference."""
    stats = {c: {"count": 0.0, "bytes": 0.0, "bytes_result": 0.0}
             for c in _COLLECTIVES}
    for name, lines in mod.computations.items():
        m = mod.multipliers.get(name, 0.0)
        if m <= 0:
            continue
        for ln in lines:
            rhs = ln.split("=", 1)
            if len(rhs) != 2:
                continue
            rhs = rhs[1]
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    result = rhs.split(c)[0]
                    rbytes = _shape_bytes(result)
                    gm = _GROUPS_RE.search(rhs)
                    n = int(gm.group(2)) if gm else 2
                    n = max(n, 2)
                    if c == "all-reduce":
                        wire = 2.0 * (n - 1) / n * rbytes
                    elif c == "all-gather":
                        wire = (n - 1) / n * rbytes
                    elif c == "reduce-scatter":
                        wire = (n - 1) * rbytes      # result is the shard
                    elif c == "all-to-all":
                        wire = (n - 1) / n * rbytes
                    else:
                        wire = rbytes
                    stats[c]["count"] += m
                    stats[c]["bytes"] += m * wire
                    stats[c]["bytes_result"] += m * rbytes
                    break
    return stats


_INSTR_RE = re.compile(r"^(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")


def _symbol_shapes(lines: List[str]) -> Dict[str, List[int]]:
    """name -> result dims for every instruction in a computation (this HLO
    dialect prints operand *names* only, so shapes must be looked up)."""
    table: Dict[str, List[int]] = {}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        sm = _SHAPE_RE.search(m.group(2))
        if sm:
            table[m.group(1)] = [int(d) for d in sm.group(2).split(",")
                                 if d]
    return table


def dot_flops(mod: HloModule) -> float:
    """2 × result_elems × contraction_size for every dot, × multiplier.
    Operand shapes resolved through the computation's symbol table."""
    total = 0.0
    for name, lines in mod.computations.items():
        m = mod.multipliers.get(name, 0.0)
        if m <= 0:
            continue
        table = _symbol_shapes(lines)
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im or " dot(" not in im.group(2):
                continue
            rhs = im.group(2)
            sm = _SHAPE_RE.search(rhs)
            if not sm:
                continue
            res_elems = 1
            for d in sm.group(2).split(","):
                if d:
                    res_elems *= int(d)
            contract = 1
            # operands print as bare names (%a, %b) or with inline
            # shapes (f32[32,32]{1,0} %a, ...) depending on the HLO
            # dialect; the operand NAMES are the last thing before each
            # comma either way, so pull them out positionally
            mdot = re.search(r"\bdot\((.*?)\)", rhs)
            mcd = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", rhs)
            if mdot and mcd:
                names = re.findall(r"%[\w.\-]+", mdot.group(1))
                rhs_dims = table.get(names[1]) if len(names) >= 2 else None
                if rhs_dims is None and len(names) >= 2:
                    # inline-shape dialect: parse the shape prefixing
                    # the second operand directly
                    pre = mdot.group(1).rsplit(names[1], 1)[0]
                    sm2 = None
                    for sm2 in _SHAPE_RE.finditer(pre):
                        pass
                    if sm2 is not None:
                        rhs_dims = [int(d) for d in
                                    sm2.group(2).split(",") if d]
                if rhs_dims:
                    for ci in mcd.group(1).split(","):
                        if ci:
                            contract *= rhs_dims[int(ci)]
            total += m * 2.0 * res_elems * contract
    return total


# ---------------------------------------------------------------------------
# Analytic terms
# ---------------------------------------------------------------------------

def analytic_model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D (dense) / 6·N_active·D (MoE),
    plus the causal-attention term 6·B·S²·H·d_h per attn layer (halved for
    causality, ×2 window fraction for local attention).  Decode shapes:
    D = one token per sequence, attention reads the full cache."""
    from repro.configs import active_param_count
    n_active = active_param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tokens = B          # one new token per sequence
        attn_ctx = S        # attends over the whole cache
    else:
        tokens = B * S
        attn_ctx = S / 2    # causal average context
    flops = 6.0 * n_active * tokens
    if shape.kind != "train":
        flops /= 3.0        # forward only
    # attention score/value FLOPs (not in 6ND)
    kinds = cfg.layer_kinds()
    n_attn = sum(1 for k in kinds if k == "attn")
    if n_attn and cfg.n_heads:
        ctx = attn_ctx
        if cfg.local_window:
            ctx = min(ctx, cfg.local_window)
        per_tok = 2 * 2 * cfg.n_heads * cfg.d_head * ctx  # qk^T + pv
        mult = 3.0 if shape.kind == "train" else 1.0
        flops += mult * n_attn * tokens * per_tok
    return flops


def analytic_hbm_bytes(cfg, shape, n_devices: int, *,
                       grad_accum: int = 1, remat_factor: float = 2.0,
                       kv_bytes: int = 2) -> float:
    """Per-device HBM traffic model (documented in EXPERIMENTS.md):

    train:  params (fwd read + bwd read, bf16) × grad_accum
            + grads (fp32 write+read) + AdamW m,v (fp32 r+w each)
            + activations: layers × local_tokens × d_model × 2B ×
              (fwd w + fwd r + remat recompute + bwd r/w ≈ 6) × remat_factor
    decode: params read once + KV cache read (+ small write) per token.
    """
    from repro.configs import param_count
    n = param_count(cfg)
    p_local = n / n_devices
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    if shape.kind == "decode":
        kinds = cfg.layer_kinds()
        n_attn = sum(1 for k in kinds if k == "attn")
        ctx = min(S, cfg.local_window) if cfg.local_window else S
        kv_traffic = (n_attn * B * ctx * cfg.n_kv_heads * cfg.d_head
                      * 2 * kv_bytes)            # k+v read per step
        state_bytes = 0.0
        if cfg.layer_pattern == "rwkv":
            H = d // cfg.rwkv_head_size
            state_bytes = L * B * H * cfg.rwkv_head_size ** 2 * 4 * 2
        if cfg.layer_pattern == "griffin":
            n_rec = sum(1 for k in kinds if k == "rglru")
            state_bytes = n_rec * B * d * 4 * 2
        return p_local * 2 + (kv_traffic + state_bytes) / n_devices
    tokens_local = B * S / n_devices
    act = L * tokens_local * d * 2 * 6 * remat_factor
    if shape.kind == "prefill":
        return p_local * 2 + act / 3.0
    param_traffic = p_local * (2 * 2 * grad_accum   # fwd+bwd reads / mb
                               + 4 + 4              # grad write+read fp32
                               + 16 + 2)            # m,v r/w fp32 + w write
    return param_traffic + act


def offload_cost_terms(h2d_bytes: float, d2h_bytes: float,
                       dispatches: float, syncs: float,
                       flops: float, kernel_bytes: float,
                       coll_bytes: float = 0.0,
                       hw: Optional[Dict[str, float]] = None
                       ) -> Dict[str, float]:
    """Static cost terms for one offload-plan execution — the roofline
    model applied to the planner's schedule (used by ``repro.core.tuner``
    to rank candidate plans):

        transfer_s   = (h2d + d2h bytes) / pcie_bw
        dispatch_s   = launch_overhead × dispatches + sync_overhead × syncs
        kernel_s     = max(flops / peak, kernel HBM bytes / hbm_bw)
        collective_s = collective wire bytes / ici_bw

    ``predicted_s`` sums the four: transfers on this machine are NOT
    overlapped with the modelled kernel time (the plan's async streams
    overlap them with *host* work), so a sum — not a max — ranks
    correctly.  Since the kernel tuning axis (ISSUE 6), ``kernel_s`` is
    no longer plan-invariant: kernel-tagged blocks are priced per tile
    variant via ``kernel_roofline_terms``, so the HBM/flops legs of the
    roofline carry cross-candidate signal too.  Since the mesh placement
    axis (ISSUE 9), ``coll_bytes`` carries the ring-volume bytes of the
    collectives GSPMD inserts for a sharded placement
    (``collective_bytes`` over the per-device HLO), priced against the
    inter-chip interconnect beside the PCIe leg; single-device plans
    leave it 0 and the term vanishes.

    ``energy_j`` (ISSUE 10) estimates the plan's data-movement + compute
    energy: bytes moved over each link × its per-byte joule constant
    (``ENERGY_TERMS``) plus flops × ``flop_j`` — the second objective of
    the tuner's time × energy × memory Pareto frontier.  The ``.get``
    fallbacks keep partially-specified ``hw`` overrides (the calibration
    fit only produces time constants) working."""
    h = hw or HW
    transfer_s = (h2d_bytes + d2h_bytes) / h["pcie_bw"]
    dispatch_s = (h["launch_overhead_s"] * dispatches
                  + h["sync_overhead_s"] * syncs)
    kernel_s = max(flops / h["peak_flops_bf16"],
                   kernel_bytes / h["hbm_bw"])
    collective_s = coll_bytes / h["ici_bw"]
    energy_j = (
        (h2d_bytes + d2h_bytes)
        * h.get("pcie_j_per_byte", HW["pcie_j_per_byte"])
        + kernel_bytes * h.get("hbm_j_per_byte", HW["hbm_j_per_byte"])
        + coll_bytes * h.get("ici_j_per_byte", HW["ici_j_per_byte"])
        + flops * h.get("flop_j", HW["flop_j"]))
    return {
        "transfer_s": transfer_s,
        "dispatch_s": dispatch_s,
        "kernel_s": kernel_s,
        "collective_s": collective_s,
        "predicted_s": transfer_s + dispatch_s + kernel_s + collective_s,
        "energy_j": energy_j,
    }


def kernel_roofline_terms(kernel: str, variant, shapes,
                          itemsizes=(),
                          hw: Optional[Dict[str, float]] = None
                          ) -> Dict[str, float]:
    """Per-kernel roofline cutout: analytic MXU flops + HBM bytes touched
    for one grid sweep of ``kernel`` launched with the tile parameters in
    ``variant`` (a dict or ``((name, value), ...)`` tuple) on operand
    ``shapes`` — the second level of the two-level (PCIe + HBM) roofline.
    Bytes follow the variant's tile revisit structure, so ``kernel_s``
    genuinely differs across tile candidates."""
    # repro.kernels.__init__ imports jax; the registry module itself is
    # stdlib-only, so pull it in directly (and lazily).
    from repro.kernels import variants as _kv
    h = hw or HW
    params = dict(variant)
    flops, kbytes = _kv.kernel_roofline(kernel, params, shapes, itemsizes)
    return {
        "flops": float(flops),
        "kernel_bytes": float(kbytes),
        "kernel_s": max(flops / h["peak_flops_bf16"], kbytes / h["hbm_bw"]),
    }


# The offload-cost constants a measured tuning table can re-fit (the
# OpenMP-Advisor observation: calibrated beats fixed for offload
# decisions).  Since the kernel tuning axis (ISSUE 6), tile variants make
# kernel_s vary across candidates, so the HBM/flops roofline legs are
# identifiable too and join the fit.  Since the mesh placement axis
# (ISSUE 9), sharded candidates carry collective wire bytes, making the
# interconnect rate identifiable the same way.
CALIBRATABLE = ("pcie_bw", "launch_overhead_s", "sync_overhead_s",
                "hbm_bw", "peak_flops_bf16", "ici_bw")

# clamp ranges keeping a degenerate fit physical: bandwidths within
# [100 MB/s, 100 TB/s], per-event overheads within [0, 100 ms],
# peak compute within [1 GFLOP/s, 1 EFLOP/s]
_FIT_BOUNDS = {
    "pcie_bw": (1e8, 1e14),
    "launch_overhead_s": (0.0, 0.1),
    "sync_overhead_s": (0.0, 0.1),
    "hbm_bw": (1e8, 1e14),
    "peak_flops_bf16": (1e9, 1e18),
    "ici_bw": (1e8, 1e14),
}

# design-matrix column order for the joint fit
_FIT_COLS = ("pcie", "dispatches", "syncs", "flops", "kbytes", "coll")


def _lstsq_cols(cols, y):
    """Scaled least squares over the non-degenerate columns.  Returns
    ({col_name: coefficient}, residual) or None when the system is
    under-determined (fewer rows than active columns)."""
    import numpy as np
    active = [n for n in _FIT_COLS if cols[n].any()]
    if not active or len(y) < len(active):
        return None
    X = np.column_stack([cols[n] for n in active])
    scale = X.max(axis=0)
    scale[scale == 0] = 1.0
    try:
        coef, *_ = np.linalg.lstsq(X / scale, y, rcond=None)
    except np.linalg.LinAlgError:
        return None
    coef = coef / scale
    resid = float(np.square(X @ coef - y).sum())
    return dict(zip(active, coef.tolist())), resid


def fit_offload_constants(rows, hw: Optional[Dict[str, float]] = None
                          ) -> Optional[Dict[str, float]]:
    """Joint least-squares fit of the CALIBRATABLE constants from a
    measured tuning table.

    ``rows`` are candidate records carrying the ``predict_cost``
    decomposition (``h2d_bytes``/``d2h_bytes``/``dispatches``/``syncs``/
    ``flops``/``kernel_bytes``) plus ``measured_s``.  The model is exactly
    ``offload_cost_terms``:

        measured ≈ bytes/pcie_bw + launch·dispatches + sync·syncs
                   + max(flops/peak, kernel_bytes/hbm_bw)

    The max() makes this piecewise linear: a row is compute-bound when its
    arithmetic intensity (flops/kernel_bytes) exceeds the machine balance
    peak/hbm_bw — which we are fitting.  But sorting rows by intensity
    reduces the assignment to ONE threshold position, so we sweep every
    split of the sorted rows, solve the then-linear system (flops column
    active on the compute side, kernel_bytes on the memory side), and keep
    the assignment with the lowest residual.  Columns that are identically
    zero (e.g. no kernel-tagged blocks in the table) drop out and their
    constants keep the incoming defaults.

    Needs ≥ 3 measured rows and at least as many rows as active columns;
    returns None when under-determined.  Fitted values are clamped to
    physical ranges; non-positive rate coefficients fall back to the
    incoming defaults."""
    import numpy as np
    h = dict(hw or HW)
    rows = [r for r in rows if r.get("measured_s") is not None]
    if len(rows) < 3:
        return None
    pcie = np.array([r["h2d_bytes"] + r["d2h_bytes"] for r in rows], float)
    disp = np.array([r["dispatches"] for r in rows], float)
    sync = np.array([r["syncs"] for r in rows], float)
    flops = np.array([r.get("flops", 0.0) or 0.0 for r in rows], float)
    kbytes = np.array([r.get("kernel_bytes", 0.0) or 0.0
                       for r in rows], float)
    coll = np.array([r.get("coll_bytes", 0.0) or 0.0 for r in rows], float)
    y = np.array([r["measured_s"] for r in rows], float)

    # arithmetic intensity; bytes-free compute rows pin to the compute
    # side (+inf), flop-free rows to the memory side (-1)
    ai = np.where(kbytes > 0, flops / np.maximum(kbytes, 1e-300),
                  np.where(flops > 0, np.inf, -1.0))
    order = np.argsort(-ai, kind="stable")    # descending intensity

    best = None
    for t in range(len(rows) + 1):
        # first t rows (by descending intensity) are compute-bound
        compute = np.zeros(len(rows), bool)
        compute[order[:t]] = True
        cols = {
            "pcie": pcie, "dispatches": disp, "syncs": sync,
            "flops": np.where(compute, flops, 0.0),
            "kbytes": np.where(compute, 0.0, kbytes),
            "coll": coll,
        }
        out = _lstsq_cols(cols, y)
        if out is not None and (best is None or out[1] < best[1]):
            best = out
    if best is None:
        return None
    coef, _ = best

    def _rate(col, default):
        c = coef.get(col)
        return 1.0 / c if c is not None and c > 0 else default

    fitted = {
        "pcie_bw": _rate("pcie", h["pcie_bw"]),
        "launch_overhead_s": coef.get("dispatches",
                                      h["launch_overhead_s"]),
        "sync_overhead_s": coef.get("syncs", h["sync_overhead_s"]),
        "peak_flops_bf16": _rate("flops", h["peak_flops_bf16"]),
        "hbm_bw": _rate("kbytes", h["hbm_bw"]),
        "ici_bw": _rate("coll", h["ici_bw"]),
    }
    for k, (lo, hi) in _FIT_BOUNDS.items():
        fitted[k] = float(min(max(fitted[k], lo), hi))
    return fitted


def _average_ranks(values) -> "np.ndarray":  # noqa: F821 - doc type
    import numpy as np
    v = np.asarray(values, float)
    order = np.argsort(v, kind="stable")
    ranks = np.empty(len(v), float)
    sv = v[order]
    i = 0
    while i < len(v):
        j = i
        while j + 1 < len(v) and sv[j + 1] == sv[i]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0
        i = j + 1
    return ranks


def rank_correlation(xs, ys) -> float:
    """Spearman rank correlation (average ranks for ties) between two
    equal-length sequences; 0.0 when either side is constant or there
    are fewer than two points.  The tuner's figure of merit: the cost
    model only has to ORDER candidates correctly, so rank correlation —
    not absolute error — is what calibration must improve."""
    if len(xs) != len(ys):
        raise ValueError("rank_correlation needs equal-length sequences")
    if len(xs) < 2:
        return 0.0
    rx, ry = _average_ranks(xs), _average_ranks(ys)
    sx, sy = rx.std(), ry.std()
    if sx == 0.0 or sy == 0.0:
        return 0.0
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))


# ---------------------------------------------------------------------------
# Cross-program candidate predictor (ISSUE 10).
#
# ``fit_offload_constants`` calibrates the analytic model from ONE
# program's measured table.  The predictor below generalizes ACROSS
# programs (the OpenMP-Advisor observation): featurize every measured
# candidate, fit one linear model on all rows the tunecache accumulated
# for a device class, and use it to price a never-measured program's
# grid — a zero-measurement cold start.
# ---------------------------------------------------------------------------

# per-candidate feature vector: the predict_cost counters, the analytic
# prior (default-constant predicted seconds — anchors the fit where the
# training programs carry no signal), and the execution knobs the
# analytic model cannot see (stream count, fusion, donation).
PREDICTOR_FEATURES = ("h2d_bytes", "d2h_bytes", "dispatches", "syncs",
                      "flops", "kernel_bytes", "coll_bytes", "kernel_s",
                      "analytic_s", "n_streams", "fuse_loops", "donate")


def candidate_features(rec) -> Dict[str, float]:
    """``PREDICTOR_FEATURES`` row for one tuner candidate record (a
    ``meta["tuning"]["candidates"]`` entry or a cached measured row).
    The knob features come from the record's ``config`` when present;
    ``analytic_s`` falls back to ``predicted_s`` for rows priced with
    default constants."""
    cfg = rec.get("config") or {}
    row = {}
    for f in PREDICTOR_FEATURES:
        if f == "n_streams":
            row[f] = float(cfg.get("n_streams", rec.get(f, 1)) or 1)
        elif f in ("fuse_loops", "donate"):
            row[f] = 1.0 if (cfg.get(f, rec.get(f)) or 0) else 0.0
        elif f == "analytic_s":
            row[f] = float(rec.get("analytic_s",
                                   rec.get("predicted_s", 0.0)) or 0.0)
        else:
            row[f] = float(rec.get(f, 0.0) or 0.0)
    return row


def fit_candidate_predictor(rows, l2: float = 1e-3) -> Optional[Dict]:
    """Fit the cross-program candidate-time model from measured rows of
    ≥ 2 distinct programs (each row: ``PREDICTOR_FEATURES`` values +
    ``measured_s`` + ``program``).  Returns ``{"features", "coef",
    "intercept", "n_rows", "n_programs"}`` or ``None`` when
    under-determined.

    Three fit choices matter for rank quality on a held-out program:

    * rows are weighted by 1 / (their program's mean measured time), so
      the fit minimizes RELATIVE error per program and a large program
      cannot drown out a small one;
    * columns are max-abs scaled and ridge-damped (``l2``);
    * coefficients are constrained non-negative by iterative clipping
      (fit, drop negative-coefficient features, refit): every feature is
      a count/size/time whose physical effect is monotone, and an
      unconstrained fit on few programs happily goes negative on a
      confounded column and then misranks the held-out grid.
    """
    import numpy as np
    rows = [r for r in rows if r.get("measured_s")]
    by_prog: Dict[str, List[float]] = {}
    for r in rows:
        by_prog.setdefault(str(r.get("program", "")), []).append(
            float(r["measured_s"]))
    if len(by_prog) < 2 or len(rows) < 4:
        return None
    mean_of = {p: sum(v) / len(v) for p, v in by_prog.items()}
    w = np.array([1.0 / max(mean_of[str(r.get("program", ""))], 1e-30)
                  for r in rows])
    X = np.array([[candidate_features(r)[f] for f in PREDICTOR_FEATURES]
                  for r in rows], float)
    y = np.array([float(r["measured_s"]) for r in rows])
    Xw = X * w[:, None]
    yw = y * w
    scale = np.abs(Xw).max(axis=0)
    scale[scale == 0] = 1.0
    Xs = Xw / scale
    active = [i for i in range(len(PREDICTOR_FEATURES)) if X[:, i].any()]
    coef = None
    while active:
        # fewer rows than columns is fine: the ridge rows below make the
        # stacked system full column rank, damping unsupported
        # coefficients toward 0, and the caller's rank-correlation
        # acceptance gate rejects a fit that still misranks
        A = np.column_stack([Xs[:, active], w])      # last col: intercept
        reg = np.sqrt(l2) * np.eye(A.shape[1])
        reg[-1, -1] = 0.0                            # intercept unpenalized
        try:
            coef, *_ = np.linalg.lstsq(
                np.vstack([A, reg]),
                np.concatenate([yw, np.zeros(A.shape[1])]), rcond=None)
        except np.linalg.LinAlgError:
            return None
        neg = {active[j] for j in range(len(active)) if coef[j] < 0}
        if not neg:
            break
        active = [i for i in active if i not in neg]
    if not active or coef is None:
        return None
    return {
        "features": list(PREDICTOR_FEATURES),
        "coef": {PREDICTOR_FEATURES[i]: float(coef[j] / scale[i])
                 for j, i in enumerate(active)},
        "intercept": float(coef[-1]),
        "n_rows": len(rows),
        "n_programs": len(by_prog),
    }


def predict_candidate_s(model: Dict, rec) -> float:
    """Price one candidate with a ``fit_candidate_predictor`` model
    (clamped at 0 — a learned intercept must not go negative on a tiny
    program)."""
    row = candidate_features(rec)
    s = float(model.get("intercept", 0.0))
    for f, c in model.get("coef", {}).items():
        s += float(c) * row.get(f, 0.0)
    return max(s, 0.0)


def roofline_terms(cfg, shape, n_devices: int, hlo_text: str, *,
                   grad_accum: int = 1, kv_bytes: int = 2
                   ) -> Dict[str, object]:
    mod = parse_hlo(hlo_text)
    colls = collective_bytes(mod)
    coll_total = sum(v["bytes"] for v in colls.values())
    hlo_f = dot_flops(mod)                    # per device
    model_f = analytic_model_flops(cfg, shape)
    mem_b = analytic_hbm_bytes(cfg, shape, n_devices,
                               grad_accum=grad_accum, kv_bytes=kv_bytes)
    t_compute = hlo_f / HW["peak_flops_bf16"]
    t_memory = mem_b / HW["hbm_bw"]
    t_coll = coll_total / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=lambda k: terms[k])
    step_time = max(t_compute, t_memory, t_coll)
    ideal = model_f / (n_devices * HW["peak_flops_bf16"])
    return {
        **terms,
        "bottleneck": bottleneck,
        "model_flops": model_f,
        "hlo_flops_per_device": hlo_f,
        "useful_ratio": model_f / max(hlo_f * n_devices, 1.0),
        "roofline_fraction": ideal / max(step_time, 1e-30),
        "collectives": colls,
        "hbm_bytes_per_device": mem_b,
    }
