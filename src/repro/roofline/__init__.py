"""Roofline analysis from compiled dry-run artifacts."""
from .analysis import (HW, analytic_hbm_bytes, analytic_model_flops,
                       collective_bytes, dot_flops, parse_hlo,
                       roofline_terms)

__all__ = ["HW", "analytic_hbm_bytes", "analytic_model_flops",
           "collective_bytes", "dot_flops", "parse_hlo", "roofline_terms"]
