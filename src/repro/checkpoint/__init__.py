"""Mesh-agnostic checkpointing with async saves."""
from .manager import CheckpointManager
__all__ = ["CheckpointManager"]
