"""Mesh-agnostic checkpointing with async (delegatestore-style) saves.

Format: one directory per step containing
    manifest.json           — tree structure, shapes, dtypes, step metadata
    <leaf-id>.npy           — one file per LOGICAL array (device-assembled)

Saving is the paper's ``delegatestore`` at system scale: the device→host
copy is issued immediately (cheap, overlapped by JAX's async dispatch), the
disk write runs on a background thread, and ``wait()`` is the
``synchronize`` barrier placed as late as possible (right before the next
save or shutdown).  Because arrays are stored as full logical values, a
checkpoint written on one mesh restores onto ANY mesh/sharding — this is
the elastic-rescale path (tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """delegatestore: device→host now (async dispatch), disk write on a
        background thread."""
        self.wait()   # previous save must land first (ordering)
        host_leaves = [(k, np.asarray(v)) for k, v in
                       _flatten_with_paths(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": step,
            "extra": extra or {},
            "treedef": str(treedef),
            "leaves": [
                {"key": k, "file": f"{i:05d}.npy",
                 "shape": list(v.shape), "dtype": str(v.dtype)}
                for i, (k, v) in enumerate(host_leaves)
            ],
        }

        def write():
            tmp = self.dir / f".tmp_step_{step:010d}"
            final = self.dir / f"step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, (_, v) in enumerate(host_leaves):
                np.save(tmp / f"{i:05d}.npy", v)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)      # atomic publish
            self._gc()

        t = threading.Thread(target=write, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        """synchronize: barrier for the in-flight save."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Optional[Any] = None
                ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``target_tree`` with optional
        shardings — the mesh/sharding may differ from save time (elastic
        rescale: the logical arrays are re-distributed on load)."""
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_key = {e["key"]: e for e in manifest["leaves"]}
        flat_t = _flatten_with_paths(target_tree)
        treedef = jax.tree_util.tree_structure(target_tree)
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            if shardings is not None else [None] * len(flat_t))
        leaves = []
        for (key, tgt), sh in zip(flat_t, sh_leaves):
            ent = by_key.get(key)
            if ent is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(d / ent["file"])
            want = tuple(getattr(tgt, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != "
                    f"target {want}")
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return (jax.tree_util.tree_unflatten(treedef, leaves),
                manifest["extra"])

    def restore_latest(self, target_tree: Any,
                       shardings: Optional[Any] = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target_tree, shardings)
        return step, tree, extra
