"""Qwen3-30B-A3B — MoE 128 experts top-8, GQA kv=4, qk-norm
[hf:Qwen/Qwen3-30B-A3B]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab=151936,
    activation="swiglu", qk_norm=True,
    n_experts=128, top_k=8,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
))
