"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay
[arXiv:2404.05892].

Sub-quadratic: state is O(1) in sequence length; long_500k runs.
heads = d_model / head_size = 40.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=8960, vocab=65536,
    activation="sq_relu",
    layer_pattern="rwkv", rwkv_head_size=64,
    sub_quadratic=True,
    source="arXiv:2404.05892",
))
