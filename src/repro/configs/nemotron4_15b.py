"""Nemotron-4 15B — dense GQA with squared-ReLU FFN [arXiv:2402.16819]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab=256000,
    activation="sq_relu",
    source="arXiv:2402.16819",
))
