"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 1:2
[arXiv:2402.19427].

Pattern (R, R, A): layers 2, 5, 8, ... are local-attention (window 2048,
MQA kv=1), the rest are RG-LRU recurrent blocks.  Sub-quadratic: long_500k
runs (recurrent state + fixed window KV).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab=256000,
    activation="geglu",
    layer_pattern="griffin", local_window=2048, rglru_conv_width=4,
    sub_quadratic=True,
    source="arXiv:2402.19427",
))
