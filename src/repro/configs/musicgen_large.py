"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone only: the EnCodec frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings (B, S, d).  4 codebooks -> 4 parallel 2048-way
output heads with per-codebook cross-entropy; kv=32 means full MHA.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048,
    activation="geglu",
    n_codebooks=4, input_embeds=True,
    source="arXiv:2306.05284",
))
