"""Chameleon-34B — early-fusion VLM over a unified token vocabulary
[arXiv:2405.09818].

The VQ image tokenizer is a STUB: inputs are a single (B, S) stream of ids
over the joint 65536 vocab (text + image tokens).  QK-norm enabled (the
paper's training-stability fix).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65536,
    activation="swiglu", qk_norm=True,
    source="arXiv:2405.09818",
))
