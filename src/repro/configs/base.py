"""Architecture + shape configuration registry.

Every assigned architecture is a frozen ``ArchConfig``; ``reduced()``
produces the small same-family variant used by the CPU smoke tests.  The
FULL configs are only ever lowered abstractly (ShapeDtypeStruct) by
``launch/dryrun.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "register", "get_config",
           "list_archs", "reduced", "param_count"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    activation: str = "swiglu"  # swiglu | sq_relu | geglu
    qkv_bias: bool = False
    qk_norm: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- hybrid / ssm ---
    layer_pattern: str = "full"   # full | griffin (R,R,A) | rwkv
    local_window: int = 0         # >0: sliding-window attention
    rglru_conv_width: int = 4
    rwkv_head_size: int = 64
    # --- io / heads ---
    n_codebooks: int = 0          # musicgen: 4 parallel output heads
    input_embeds: bool = False    # frontend STUB supplies (B, S, d) embeds
    # --- numerics ---
    dtype: str = "bfloat16"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    # --- capability flags ---
    sub_quadratic: bool = False   # True => long_500k is runnable
    source: str = ""              # provenance note

    @property
    def attn_free(self) -> bool:
        return self.layer_pattern == "rwkv"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kind(self, i: int) -> str:
        """'attn' | 'rglru' | 'rwkv' for layer i (griffin: R,R,A pattern)."""
        if self.layer_pattern == "griffin":
            return "attn" if i % 3 == 2 else "rglru"
        if self.layer_pattern == "rwkv":
            return "rwkv"
        return "attn"

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.n_layers))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from . import ALL_ARCHS  # noqa: F401
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    from . import ALL_ARCHS  # noqa: F401
    return tuple(sorted(_REGISTRY))


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small same-family config for CPU smoke tests: few layers (enough to
    cover a full hybrid pattern), tiny width/vocab, few experts."""
    n_layers = 3 if cfg.layer_pattern == "griffin" else 2
    n_heads = 0 if cfg.attn_free else 4
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads if not cfg.attn_free else 0,
        n_kv_heads=0 if cfg.attn_free else (1 if cfg.n_kv_heads == 1 else 2),
        d_head=16,
        d_ff=96 if not cfg.is_moe else 32,
        vocab=257,
        n_experts=8 if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        local_window=32 if cfg.local_window else 0,
        rwkv_head_size=16,
        dtype="float32",
    )


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (used for 6ND in the roofline report)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    total = v * d                       # embedding
    if cfg.n_codebooks:
        total += cfg.n_codebooks * v * d    # per-codebook output heads
    else:
        total += v * d                      # untied LM head
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        total += 2 * d                      # 2 norms
        if kind == "attn":
            q = cfg.n_heads * cfg.d_head
            kv = cfg.n_kv_heads * cfg.d_head
            total += d * q + 2 * d * kv + q * d
            if cfg.qkv_bias:
                total += q + 2 * kv
        elif kind == "rglru":
            # in/out proj + conv + gates (x2 branch) + recurrence params
            total += 2 * d * d + cfg.rglru_conv_width * d + 2 * d * d + 2 * d
        elif kind == "rwkv":
            # time-mix: r,k,v,w,g projections + output + lora + decay
            total += 5 * d * d + d * d + 6 * d + 2 * (d * 32 + 32 * d)
        # FFN
        if cfg.is_moe:
            if cfg.activation in ("swiglu", "geglu"):
                e_params = 3 * d * f
            else:
                e_params = 2 * d * f
            total += cfg.n_experts * e_params + d * cfg.n_experts  # + router
            if cfg.moe_dense_residual:
                total += e_params
        elif kind != "rwkv":
            if cfg.activation in ("swiglu", "geglu"):
                total += 3 * d * f
            else:
                total += 2 * d * f
        else:
            total += 2 * d * f              # rwkv channel-mix (r + k/v)
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k of n_experts) for 6·N_active·D."""
    if not cfg.is_moe:
        return param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    e_params = (3 if cfg.activation in ("swiglu", "geglu") else 2) * d * f
    return param_count(cfg) - cfg.n_layers * \
        (cfg.n_experts - cfg.top_k) * e_params
