"""Command-R 35B — dense GQA, no bias [hf:CohereForAI/c4ai-command-r-v01].

Assumption (noted in DESIGN.md): the real model uses a parallel
attention+FFN block; we model the standard sequential residual form.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22528, vocab=256000,
    activation="swiglu", qkv_bias=False,
    rope_theta=8_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01",
))
