"""Config registry: one module per assigned architecture (+ polybench)."""
from .base import (ArchConfig, ShapeSpec, SHAPES, get_config, list_archs,
                   param_count, active_param_count, reduced, register)

from . import qwen2_5_14b, internlm2_20b, command_r_35b, nemotron4_15b, \
    qwen3_moe_30b_a3b, arctic_480b, recurrentgemma_2b, musicgen_large, \
    chameleon_34b, rwkv6_3b
from .polybench import POLYBENCH_PROBLEMS

ALL_ARCHS = (
    qwen2_5_14b.CONFIG, internlm2_20b.CONFIG, command_r_35b.CONFIG,
    nemotron4_15b.CONFIG, qwen3_moe_30b_a3b.CONFIG, arctic_480b.CONFIG,
    recurrentgemma_2b.CONFIG, musicgen_large.CONFIG, chameleon_34b.CONFIG,
    rwkv6_3b.CONFIG,
)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs",
           "param_count", "active_param_count", "reduced", "register",
           "ALL_ARCHS", "POLYBENCH_PROBLEMS"]
