"""Config registry: one module per assigned architecture (+ polybench)."""
from . import (arctic_480b, chameleon_34b, command_r_35b, internlm2_20b,
               musicgen_large, nemotron4_15b, qwen2_5_14b, qwen3_moe_30b_a3b,
               recurrentgemma_2b, rwkv6_3b)
from .base import (SHAPES, ArchConfig, ShapeSpec, active_param_count,
                   get_config, list_archs, param_count, reduced, register)
from .polybench import POLYBENCH_PROBLEMS

ALL_ARCHS = (
    qwen2_5_14b.CONFIG, internlm2_20b.CONFIG, command_r_35b.CONFIG,
    nemotron4_15b.CONFIG, qwen3_moe_30b_a3b.CONFIG, arctic_480b.CONFIG,
    recurrentgemma_2b.CONFIG, musicgen_large.CONFIG, chameleon_34b.CONFIG,
    rwkv6_3b.CONFIG,
)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs",
           "param_count", "active_param_count", "reduced", "register",
           "ALL_ARCHS", "POLYBENCH_PROBLEMS"]
