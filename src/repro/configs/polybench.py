"""The paper's own workload: Polybench block-programs (3MM, GEMM, ...).

Not an LM architecture — these are the offload programs used by the paper's
Tables/Figures; see ``repro.polybench`` for the program builders and
``benchmarks/`` for the speedup comparisons.
"""
POLYBENCH_PROBLEMS = (
    "2mm", "3mm", "gemm", "atax", "bicg", "mvt", "gesummv", "syrk",
    "covariance", "jacobi2d",
)
