"""Snowflake Arctic 480B — MoE 128e top-2 + dense residual branch
[hf:Snowflake/snowflake-arctic-base].

Assumption (DESIGN.md): the dense residual FFN uses hidden = d_ff (4864).
Default optimizer for this config is adafactor (Adam fp32 state for 480B
exceeds one pod's HBM); the host-offloaded Adam variant is the framework's
paper-technique alternative (optim/offload.py).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864, vocab=32000,
    activation="swiglu",
    n_experts=128, top_k=2, moe_dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
))
