"""Runtime device-residency tracker — the ``noupdate``/``mapbyname`` machinery
used by the training-loop substrates (data pipeline, optimizer offload,
async checkpointing) outside the block-program executor.

A ``DeviceResidency`` owns named buffers that may have a host copy, a device
copy, or both, and performs transfers lazily with the paper's policy:
uploads as early as the caller schedules them (``prefetch`` = advancedload),
downloads as late as possible (``fetch`` only when the host actually reads =
delegatestore), and no transfer at all when the requested space already holds
a valid copy (noupdate).  All movement is instrumented.

Transfers go through a pluggable ``Backend`` (``repro.core.backend``), so
prefetches are enqueued asynchronously on a per-entry transfer stream and
``wait()`` is a real synchronization point (HMPP ``synchronize``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import numpy as np

from .backend import Backend, get_backend

__all__ = ["DeviceResidency", "ResidencyStats", "plan_peak_device_bytes"]


@dataclasses.dataclass
class ResidencyStats:
    h2d_transfers: int = 0
    h2d_bytes: int = 0
    d2h_transfers: int = 0
    d2h_bytes: int = 0
    elided: int = 0
    h2d_time: float = 0.0
    d2h_time: float = 0.0


@dataclasses.dataclass
class _Entry:
    host: Optional[np.ndarray] = None
    device: Optional[Any] = None
    valid_host: bool = False
    valid_device: bool = False
    stream: int = 0


def _leaf_bytes(x) -> int:
    return int(np.prod(np.shape(x))) * np.dtype(
        getattr(x, "dtype", np.float32)).itemsize


class DeviceResidency:
    def __init__(self, device=None, *, backend: Any = None):
        self._entries: Dict[str, _Entry] = {}
        self.stats = ResidencyStats()
        if backend is None and device is not None:
            from .backend import JaxDeviceBackend
            backend = JaxDeviceBackend(device)
        self._backend: Backend = get_backend(backend)
        self._next_stream = 1

    # -- host side ---------------------------------------------------------
    def put_host(self, name: str, value: np.ndarray) -> None:
        """A host write: invalidates any device copy (paper: CPU write ⇒
        re-advancedload needed)."""
        e = self._entries.setdefault(name, _Entry())
        if e.stream == 0:
            e.stream = self._next_stream
            self._next_stream += 1
        e.host = np.asarray(value)
        e.valid_host, e.valid_device = True, False

    def fetch(self, name: str) -> np.ndarray:
        """Host read — delegatestore happens here, as late as possible."""
        e = self._entries[name]
        if e.valid_host:
            self.stats.elided += 1
            return e.host
        t = time.perf_counter()
        e.host = self._backend.download(e.device, stream=e.stream)
        self.stats.d2h_time += time.perf_counter() - t
        self.stats.d2h_transfers += 1
        self.stats.d2h_bytes += _leaf_bytes(e.host)
        e.valid_host = True
        return e.host

    # -- device side -------------------------------------------------------
    def put_device(self, name: str, value) -> None:
        """A device write (kernel output): invalidates the host copy."""
        e = self._entries.setdefault(name, _Entry())
        e.device = value
        e.valid_device, e.valid_host = True, False

    def prefetch(self, name: str) -> None:
        """advancedload: enqueue the upload now (async, on this entry's
        transfer stream) so it overlaps whatever runs next; no-op if
        already resident."""
        e = self._entries[name]
        if e.valid_device:
            self.stats.elided += 1
            return
        t = time.perf_counter()
        e.device = self._backend.upload(e.host, stream=e.stream)
        self.stats.h2d_time += time.perf_counter() - t
        self.stats.h2d_transfers += 1
        self.stats.h2d_bytes += _leaf_bytes(e.host)
        e.valid_device = True

    def device_value(self, name: str):
        """Device read; uploads on demand (the *unoptimized* path — callers
        that care should have prefetched)."""
        e = self._entries[name]
        if not e.valid_device:
            self.prefetch(name)
        return e.device

    def wait(self, name: Optional[str] = None) -> None:
        """Block until outstanding async transfers complete (HMPP
        ``synchronize``): one entry's stream, or every stream."""
        if name is None:
            self._backend.sync()
        else:
            self._backend.sync(self._entries[name].stream)

    def resident(self, name: str) -> bool:
        e = self._entries.get(name)
        return bool(e and e.valid_device)

    def release(self, name: Optional[str] = None) -> None:
        names = [name] if name else list(self._entries)
        for n in names:
            e = self._entries[n]
            if e.device is not None:
                self._backend.free(e.device)
            e.device = None
            e.valid_device = False


# ---------------------------------------------------------------------------
# Static peak-residency walk (ISSUE 10) — the tuner's peak-memory objective.
# ---------------------------------------------------------------------------

def _plan_group_vars(pl, group: int) -> set:
    """Vars a ``Release`` of ``group`` frees: the group's ``mapbyname``
    declaration plus everything its member codelets read or write.  Local
    mirror of ``executor.group_vars`` — the executor pulls in the whole
    backend stack, which this jax-free walk must not."""
    from .ir import GroupDecl
    names: set = set()
    for d in pl.directives(GroupDecl):
        if d.group == group:
            names.update(d.mapbyname)
    for bi in pl.groups.get(group, ()):
        blk = pl.program.blocks[bi]
        names.update(blk.reads)
        names.update(blk.writes)
    return names


def _kernel_workset_bytes(blk, kernel_variants, shapes) -> float:
    """On-chip tile working set of a kernel-tagged block under the
    candidate's chosen tile ``params`` (``kernel_variants`` maps kernel
    name -> params; registry defaults otherwise).  0 when shapes are
    unavailable or the tile does not validate — the walk then ranks on
    HBM residency alone, which is the plan-dependent part anyway."""
    if not getattr(blk, "kernel", None) or not shapes:
        return 0.0
    try:
        from repro.kernels.variants import KERNELS, kernel_workset
        sds = [shapes[v] for v in blk.reads]
        op_shapes = [tuple(s.shape) for s in sds]
        itemsizes = [int(np.dtype(s.dtype).itemsize) for s in sds]
        params = (kernel_variants or {}).get(blk.kernel)
        if params is None:
            params = KERNELS[blk.kernel]["defaults"]
        return float(kernel_workset(blk.kernel, dict(params), op_shapes,
                                    itemsizes))
    except Exception:
        return 0.0


def plan_peak_device_bytes(pl, *, donate: bool = False,
                           kernel_variants: Optional[Dict] = None,
                           shapes: Optional[Dict] = None) -> float:
    """Peak device bytes of one walk over the plan's ops — the tuner's
    third objective (time × energy × **memory**).

    The walk tracks the set of device-allocated buffers exactly as the
    executor would create them: ``AdvancedLoad`` allocates its var,
    an offload block allocates any not-yet-resident actual read plus its
    outputs, ``Release`` frees its group's vars (``mapbyname`` + member
    reads/writes).  ``DelegateStore`` does NOT free — HMPP keeps the
    device copy valid until the group releases.

    At each offload callsite the peak candidate additionally charges:

    * **transients** — dummy device zeros for declared-but-unread
      operands, and output double-buffering for every written var whose
      old device buffer cannot be reused (not resident, or resident but
      ``donate=False``): briefly both the old input and the new output
      exist, which is why donation is a memory knob, not just a time one;
    * **kernel tile working set** — ``kernel_workset`` of the block's
      kernel under the candidate's tile choice (``kernel_variants``),
      so the kernel axis moves this objective: bigger tiles run faster
      (fewer passes over HBM) but hold a larger slice on-chip.

    ``shapes`` is the analyzer's var -> ShapeDtypeStruct map (for kernel
    operand shapes); byte sizes come from ``pl.meta["var_nbytes"]``.
    Returns bytes (float); vars with unknown size count 0.
    """
    from .ir import AdvancedLoad, BlockKind, Release
    nb: Dict[str, float] = dict(pl.meta.get("var_nbytes") or {})
    resident: Dict[str, float] = {}
    peak = 0.0
    for op in pl.ops:
        if op.kind == "directive":
            d = op.directive
            if isinstance(d, AdvancedLoad):
                resident.setdefault(d.var, float(nb.get(d.var, 0)))
            elif isinstance(d, Release):
                for v in _plan_group_vars(pl, d.group):
                    resident.pop(v, None)
            continue
        if op.kind != "block":
            continue
        blk = pl.program.blocks[op.block_idx]
        if blk.kind is not BlockKind.OFFLOAD:
            continue
        actual = set(blk.effective_reads())
        transient = 0.0
        for v in blk.reads:
            if v not in actual:        # dummy zeros arg, freed after launch
                transient += float(nb.get(v, 0))
            else:                      # upload-on-demand stays resident
                resident.setdefault(v, float(nb.get(v, 0)))
        for w in blk.writes:           # output double-buffer unless donated
            if w not in resident or not donate:
                transient += float(nb.get(w, 0))
        transient += _kernel_workset_bytes(blk, kernel_variants, shapes)
        peak = max(peak, sum(resident.values()) + transient)
        for w in blk.writes:
            resident[w] = float(nb.get(w, 0))
    return max(peak, sum(resident.values()))
