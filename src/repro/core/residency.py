"""Runtime device-residency tracker — the ``noupdate``/``mapbyname`` machinery
used by the training-loop substrates (data pipeline, optimizer offload,
async checkpointing) outside the block-program executor.

A ``DeviceResidency`` owns named buffers that may have a host copy, a device
copy, or both, and performs transfers lazily with the paper's policy:
uploads as early as the caller schedules them (``prefetch`` = advancedload),
downloads as late as possible (``fetch`` only when the host actually reads =
delegatestore), and no transfer at all when the requested space already holds
a valid copy (noupdate).  All movement is instrumented.

Transfers go through a pluggable ``Backend`` (``repro.core.backend``), so
prefetches are enqueued asynchronously on a per-entry transfer stream and
``wait()`` is a real synchronization point (HMPP ``synchronize``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import numpy as np

from .backend import Backend, get_backend

__all__ = ["DeviceResidency", "ResidencyStats"]


@dataclasses.dataclass
class ResidencyStats:
    h2d_transfers: int = 0
    h2d_bytes: int = 0
    d2h_transfers: int = 0
    d2h_bytes: int = 0
    elided: int = 0
    h2d_time: float = 0.0
    d2h_time: float = 0.0


@dataclasses.dataclass
class _Entry:
    host: Optional[np.ndarray] = None
    device: Optional[Any] = None
    valid_host: bool = False
    valid_device: bool = False
    stream: int = 0


def _leaf_bytes(x) -> int:
    return int(np.prod(np.shape(x))) * np.dtype(
        getattr(x, "dtype", np.float32)).itemsize


class DeviceResidency:
    def __init__(self, device=None, *, backend: Any = None):
        self._entries: Dict[str, _Entry] = {}
        self.stats = ResidencyStats()
        if backend is None and device is not None:
            from .backend import JaxDeviceBackend
            backend = JaxDeviceBackend(device)
        self._backend: Backend = get_backend(backend)
        self._next_stream = 1

    # -- host side ---------------------------------------------------------
    def put_host(self, name: str, value: np.ndarray) -> None:
        """A host write: invalidates any device copy (paper: CPU write ⇒
        re-advancedload needed)."""
        e = self._entries.setdefault(name, _Entry())
        if e.stream == 0:
            e.stream = self._next_stream
            self._next_stream += 1
        e.host = np.asarray(value)
        e.valid_host, e.valid_device = True, False

    def fetch(self, name: str) -> np.ndarray:
        """Host read — delegatestore happens here, as late as possible."""
        e = self._entries[name]
        if e.valid_host:
            self.stats.elided += 1
            return e.host
        t = time.perf_counter()
        e.host = self._backend.download(e.device, stream=e.stream)
        self.stats.d2h_time += time.perf_counter() - t
        self.stats.d2h_transfers += 1
        self.stats.d2h_bytes += _leaf_bytes(e.host)
        e.valid_host = True
        return e.host

    # -- device side -------------------------------------------------------
    def put_device(self, name: str, value) -> None:
        """A device write (kernel output): invalidates the host copy."""
        e = self._entries.setdefault(name, _Entry())
        e.device = value
        e.valid_device, e.valid_host = True, False

    def prefetch(self, name: str) -> None:
        """advancedload: enqueue the upload now (async, on this entry's
        transfer stream) so it overlaps whatever runs next; no-op if
        already resident."""
        e = self._entries[name]
        if e.valid_device:
            self.stats.elided += 1
            return
        t = time.perf_counter()
        e.device = self._backend.upload(e.host, stream=e.stream)
        self.stats.h2d_time += time.perf_counter() - t
        self.stats.h2d_transfers += 1
        self.stats.h2d_bytes += _leaf_bytes(e.host)
        e.valid_device = True

    def device_value(self, name: str):
        """Device read; uploads on demand (the *unoptimized* path — callers
        that care should have prefetched)."""
        e = self._entries[name]
        if not e.valid_device:
            self.prefetch(name)
        return e.device

    def wait(self, name: Optional[str] = None) -> None:
        """Block until outstanding async transfers complete (HMPP
        ``synchronize``): one entry's stream, or every stream."""
        if name is None:
            self._backend.sync()
        else:
            self._backend.sync(self._entries[name].stream)

    def resident(self, name: str) -> bool:
        e = self._entries.get(name)
        return bool(e and e.valid_device)

    def release(self, name: Optional[str] = None) -> None:
        names = [name] if name else list(self._entries)
        for n in names:
            e = self._entries[n]
            if e.device is not None:
                self._backend.free(e.device)
            e.device = None
            e.valid_device = False
