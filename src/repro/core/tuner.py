"""Plan-space explorer — the search the paper actually describes.

OMP2HMPP's headline result (§3) comes from *exploring the space of
directive combinations*: the tool emits many candidate HMPP versions and
picks the best.  This module does that over the pass pipeline
(``repro.core.passes``): enumerate candidate plans across the axes the
paper explores —

    placement policy     naive / optimized / grouped (registry-extensible)
    transfer streams     1–4 logical upload/download queues
    loop fusion          whole-loop ``lax.fori_loop`` lowering on/off
    buffer donation      fused launches donate rewritten inputs on/off

— rank them with a static cost model that reuses the roofline machinery
(``repro.roofline.analysis``: per-block HLO dot-FLOPs, PCIe/HBM
bandwidths, launch overhead × dispatch count), optionally refine the
top-k by measured wall time, and return the winner with the full ranked
table in ``plan.meta["tuning"]``.

Entry point: ``tune(program, backend=...)``, or equivalently
``plan(program, policy="auto", backend=...)``.

Candidates that fail the pipeline's ``SimulateFixPass`` (an invalid
placement) are recorded with ``valid=False`` and are never ranked or
measured — the explorer only ever returns a simulator-approved plan.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..roofline.analysis import HW, dot_flops, offload_cost_terms, parse_hlo
from .analysis import ProgramAnalysis, analyze
from .backend import Backend, JaxDeviceBackend, get_backend
from .ir import (AdvancedLoad, BlockKind, DelegateStore, Plan, Program,
                 Synchronize)
from .passes import Pipeline

__all__ = ["PlanConfig", "enumerate_configs", "predict_cost", "tune",
           "winner_exec_kwargs"]


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """One point of the plan space."""
    policy: str = "optimized"
    n_streams: int = 2
    fuse_loops: bool = True
    donate: bool = False

    @property
    def label(self) -> str:
        return (f"{self.policy}/streams{self.n_streams}"
                f"/{'fuse' if self.fuse_loops else 'nofuse'}"
                f"/{'donate' if self.donate else 'nodonate'}")

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


DEFAULT_POLICIES: Tuple[str, ...] = ("naive", "optimized", "grouped")
DEFAULT_STREAMS: Tuple[int, ...] = (1, 2, 3, 4)


def enumerate_configs(policies: Sequence[str] = DEFAULT_POLICIES,
                      streams: Sequence[int] = DEFAULT_STREAMS,
                      fuse: Sequence[bool] = (True, False),
                      donate: Sequence[bool] = (False, True)
                      ) -> List[PlanConfig]:
    return [PlanConfig(policy=p, n_streams=s, fuse_loops=f, donate=d)
            for p, s, f, d in itertools.product(policies, streams,
                                                fuse, donate)]


# --------------------------------------------------------------------------
# Static cost model.
# --------------------------------------------------------------------------

def _block_flops(program: Program,
                 shapes: Dict[str, Any]) -> Dict[int, float]:
    """Per-offload-block FLOPs via the roofline HLO machinery: lower each
    block body once, parse the optimized HLO, count dot FLOPs.  Falls
    back to 0 for bodies that fail to lower (the cost model then ranks
    on transfer + dispatch terms alone, which are the plan-dependent
    ones anyway)."""
    out: Dict[int, float] = {}
    try:
        import jax
        import jax.numpy as jnp
    except Exception:            # pragma: no cover - jax is baked in
        return {b.idx: 0.0 for b in program.offload_blocks()}
    for blk in program.offload_blocks():
        avals = [shapes[v] for v in blk.reads]

        def wrapped(*arrays, _blk=blk):
            o = _blk.fn(jnp, **dict(zip(_blk.reads, arrays)))
            return tuple(o[w] for w in _blk.writes)

        try:
            txt = jax.jit(wrapped).lower(*avals).compile().as_text()
            out[blk.idx] = dot_flops(parse_hlo(txt))
        except Exception:
            out[blk.idx] = 0.0
    return out


def predict_cost(pl: Plan, cfg: PlanConfig,
                 block_flops: Optional[Dict[int, float]] = None
                 ) -> Dict[str, Any]:
    """Walk the plan with loop-trip multipliers and price it:

    * transfer bytes  — Σ nbytes(var) × trip multiplier per load/store,
    * dispatches      — physical launches: per-iteration blocks and
      transfers, but a fusable pure-device loop nest counts ONCE per
      entry when ``cfg.fuse_loops`` (the whole-loop lowering's
      amortization, mirroring the compiler's structural eligibility),
    * kernel terms    — logical block launches × per-block HLO FLOPs and
      touched bytes (plan-invariant; keeps predictions in real units).

    Returns the counters plus ``offload_cost_terms`` (transfer_s /
    dispatch_s / kernel_s / predicted_s).
    """
    from .compile import fusable_loops
    program = pl.program
    nb = pl.meta.get("var_nbytes", {})
    flops_of = block_flops or {}
    pure = fusable_loops(pl) if cfg.fuse_loops else set()

    h2d_bytes = d2h_bytes = 0
    loads = stores = syncs = 0
    kernel_launches = 0          # logical
    dispatches = 0.0             # physical (fused nests count once)
    flops = 0.0
    kernel_bytes = 0.0

    mult_stack: List[int] = []
    fused_depth = 0

    def mult() -> int:
        m = 1
        for n in mult_stack:
            m *= n
        return m

    for op in pl.ops:
        if op.kind == "loop_begin":
            if fused_depth or op.loop_id in pure:
                if fused_depth == 0:
                    # one launch per entry of the nest — times the trip
                    # count of any enclosing UNFUSED loops (a pure inner
                    # loop under an impure outer re-launches per outer
                    # iteration; mult_stack has not pushed this loop yet)
                    dispatches += mult()
                fused_depth += 1
            mult_stack.append(program.loops[op.loop_id].n_iters)
        elif op.kind == "loop_end":
            mult_stack.pop()
            if fused_depth:
                fused_depth -= 1
        elif op.kind == "block":
            blk = program.blocks[op.block_idx]
            if blk.kind is not BlockKind.OFFLOAD:
                continue
            m = mult()
            kernel_launches += m
            if fused_depth == 0:
                dispatches += m
            flops += flops_of.get(blk.idx, 0.0) * m
            touched = set(blk.effective_reads()) | set(blk.writes)
            kernel_bytes += sum(nb.get(v, 0) for v in touched) * m
        elif op.kind == "directive":
            d = op.directive
            m = mult()
            if isinstance(d, AdvancedLoad):
                loads += m
                h2d_bytes += nb.get(d.var, 0) * m
                dispatches += m
            elif isinstance(d, DelegateStore):
                stores += m
                d2h_bytes += nb.get(d.var, 0) * m
                dispatches += m
            elif isinstance(d, Synchronize):
                syncs += m

    terms = offload_cost_terms(h2d_bytes, d2h_bytes, dispatches, syncs,
                               flops, kernel_bytes)
    return {
        "h2d_bytes": int(h2d_bytes), "d2h_bytes": int(d2h_bytes),
        "loads": int(loads), "stores": int(stores), "syncs": int(syncs),
        "kernel_launches": int(kernel_launches),
        "dispatches": float(dispatches), "flops": float(flops),
        "kernel_bytes": float(kernel_bytes), **terms,
    }


# --------------------------------------------------------------------------
# Measurement.
# --------------------------------------------------------------------------

def _donation_variant(be: Backend, donate: bool) -> Backend:
    """``be`` with donation switched to ``donate`` (a cached twin when
    they differ, in EITHER direction — a donate=True backend passed by
    the caller must not leak donation into nodonate candidates).
    Backends without a donation concept measure both as themselves."""
    if isinstance(be, JaxDeviceBackend) and be.donate != donate:
        attr = "_donate_twin" if donate else "_nodonate_twin"
        twin = getattr(be, attr, None)
        if twin is None:
            twin = type(be)(device=be._device, n_streams=be.n_streams,
                            donate=donate)
            setattr(be, attr, twin)
        return twin
    return be


def _measurable(program: Program) -> bool:
    return all(type(v).__name__ != "ShapeDtypeStruct"
               for v in program.inputs.values())


def _measure(pl: Plan, cfg: PlanConfig, be: Backend, reps: int) -> float:
    from .executor import execute
    kw = dict(mode="compiled", fuse_loops=cfg.fuse_loops,
              backend=_donation_variant(be, cfg.donate))
    execute(pl, **kw)                       # warm jits + plan lowering
    best = float("inf")
    for _ in range(max(1, reps)):
        _, s = execute(pl, **kw)
        best = min(best, s.wall_time)       # steady-state, compile excluded
    return best


def winner_exec_kwargs(pl: Plan, backend: Any = None) -> Dict[str, Any]:
    """``execute()`` kwargs that honor a tuned plan's chosen variant:
    compiled mode with the winner's fusion flag, on a donate-enabled
    twin of ``backend`` when the winner wants donation.  Without this a
    caller re-running the winner on the plain backend measures the
    nodonate timing under a donate label."""
    be = _donation_variant(get_backend(backend),
                           bool(pl.meta.get("donate")))
    return dict(mode="compiled",
                fuse_loops=bool(pl.meta.get("fuse_loops", True)),
                backend=be)


# --------------------------------------------------------------------------
# The explorer.
# --------------------------------------------------------------------------

def tune(program: Program, *, backend: Any = None,
         analysis: Optional[ProgramAnalysis] = None,
         policies: Sequence[str] = DEFAULT_POLICIES,
         streams: Sequence[int] = DEFAULT_STREAMS,
         fuse: Sequence[bool] = (True, False),
         donate: Sequence[bool] = (False, True),
         configs: Optional[Sequence[PlanConfig]] = None,
         measure: bool = True, top_k: Optional[int] = None,
         reps: int = 2) -> Plan:
    """Explore the plan space; return the winning ``Plan``.

    Candidates with identical ops and execution flags are deduplicated
    (the merged config labels land in the survivor's ``aliases``); every
    unique candidate is priced by ``predict_cost`` and — when ``measure``
    and the program's inputs are concrete — run ``reps`` times on
    ``backend`` (all of them, or only the predicted top-``top_k``).
    Candidates are CONFIG-distinct, not always execution-distinct: fuse
    on a loop-free plan, donate on a non-donating backend, or a streams
    axis above the backend's physical queue count all measure the same
    execution under different labels, and noise picks among them — by
    design, so the table enumerates the full axis grid the paper
    explores (see ROADMAP for the planned dominance pruning).  The
    winner is the best *measured* candidate (predicted order breaks
    ties / decides when measurement is off), returned with:

        plan.meta["tuning"]   {"chosen", "backend", "hw", "candidates"}
                              — candidates ranked by predicted cost,
                              each with predicted AND measured seconds
        plan.meta["fuse_loops"] / ["donate"]
                              — how the winner wants to be executed
    """
    an = analysis or analyze(program)
    be = get_backend(backend)
    cfg_list = list(configs) if configs is not None else enumerate_configs(
        policies, streams, fuse, donate)
    if not cfg_list:
        raise ValueError("tune() needs at least one candidate config")

    flops_cache: Optional[Dict[int, float]] = None
    records: List[Dict[str, Any]] = []
    plans: Dict[str, Plan] = {}
    seen: Dict[Tuple, Dict[str, Any]] = {}

    for cfg in cfg_list:
        base = {"label": cfg.label, "config": cfg.as_dict(),
                "aliases": [], "valid": True, "error": None,
                "measured_s": None, "rank": None}
        try:
            pl = Pipeline.default(cfg.policy, n_streams=cfg.n_streams
                                  ).run(program, analysis=an)
        except (RuntimeError, ValueError) as e:
            base.update(valid=False, error=str(e))
            records.append(base)
            continue
        # the ops tuple itself (frozen dataclasses) keys the dedupe —
        # exact, unlike its hash, which could collide two distinct plans
        key = (tuple(pl.ops), cfg.fuse_loops, cfg.donate)
        if key in seen:
            seen[key]["aliases"].append(cfg.label)
            continue
        if flops_cache is None:
            flops_cache = _block_flops(program, an.shapes)
        base.update(predict_cost(pl, cfg, flops_cache))
        seen[key] = base
        records.append(base)
        plans[cfg.label] = pl

    valid = [r for r in records if r["valid"]]
    if not valid:
        raise RuntimeError(
            "plan-space exploration found no valid candidate: "
            + "; ".join(f"{r['label']}: {r['error']}" for r in records))
    valid.sort(key=lambda r: r["predicted_s"])
    for i, r in enumerate(valid):
        r["rank"] = i + 1

    if measure and _measurable(program):
        to_measure = valid if top_k is None else valid[:max(1, top_k)]
        for r in to_measure:
            cfg = PlanConfig(**r["config"])
            r["measured_s"] = _measure(plans[r["label"]], cfg, be, reps)

    measured = [r for r in valid if r["measured_s"] is not None]
    chosen = (min(measured, key=lambda r: r["measured_s"]) if measured
              else valid[0])

    best = plans[chosen["label"]]
    best.meta["tuning"] = {
        "chosen": chosen["label"],
        "backend": be.name,
        "hw": {k: HW[k] for k in ("pcie_bw", "hbm_bw", "peak_flops_bf16",
                                  "launch_overhead_s", "sync_overhead_s")},
        "candidates": valid + [r for r in records if not r["valid"]],
    }
    best.meta["fuse_loops"] = chosen["config"]["fuse_loops"]
    best.meta["donate"] = chosen["config"]["donate"]
    best.meta["optimize"] = chosen["config"]["policy"] != "naive"
    return best
