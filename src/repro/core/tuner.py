"""Plan-space explorer — the search the paper actually describes.

OMP2HMPP's headline result (§3) comes from *exploring the space of
directive combinations*: the tool emits many candidate HMPP versions and
picks the best.  This module does that over the pass pipeline
(``repro.core.passes``): enumerate candidate plans across the axes the
paper explores —

    placement policy     naive / optimized / grouped (registry-extensible)
    transfer streams     1–4 logical upload/download queues
    loop fusion          whole-loop ``lax.fori_loop`` lowering on/off
    buffer donation      fused launches donate rewritten inputs on/off
    kernel variants      per-Pallas-kernel tile/block sizes (ISSUE 6):
                         each kernel-tagged block's registry grid
                         (``repro.kernels.variants``), priced by a
                         per-kernel roofline cutout so ``kernel_s``
                         differs across tile candidates
    mesh placement       replicate / fsdp / tp per-variable sharding on
                         placement-capable backends (ISSUE 9,
                         ``distributed.mesh_backend``): priced off the
                         post-SPMD HLO (per-device flops + collective
                         wire bytes against ``ici_bw``), measured on a
                         ``with_placement`` twin, recorded in
                         ``meta["mesh"]``; "" (absent) on single-device
                         backends, whose grid is byte-identical to the
                         pre-mesh one

— rank them with a static cost model that reuses the roofline machinery
(``repro.roofline.analysis``: per-block HLO dot-FLOPs, PCIe/HBM
bandwidths, launch overhead × dispatch count), measure the distinct
candidates, and return the winner with the full ranked table in
``plan.meta["tuning"]``.

ISSUE 5 additions on top of the PR-3 explorer:

*Dominance pruning* — configs that are execution-identical (a streams
axis with < 2 groups, donate on a backend without donation, fuse on a
plan with no fusable loops) are merged into one *execution class*: the
class survivor is measured ONCE and the merged configs inherit its
numbers, carrying ``alias_of`` so the table still enumerates the full
axis grid the paper explores.  Candidates that ARE measured run on a
physically matching backend (``Backend.variant``: a streams-3 config on
a 3-queue backend, donate on a donating twin).

*Persistent cache* — measured results are keyed on a content
fingerprint of (program ops, backend identity, candidate grid + protocol,
cost-model version) in ``repro.core.tunecache``; a repeated
``policy="auto"`` call returns the cached winner with zero measurements
and a byte-identical table.  ``refresh=True`` re-measures.

*Measured calibration* — after measuring, ``pcie_bw`` /
``launch_overhead_s`` / ``sync_overhead_s`` are re-fitted by least
squares from the (predicted-terms, measured-time) table
(``fit_offload_constants``); the fit is kept only when it does not lower
the predicted-vs-measured rank correlation (both correlations are
recorded in ``meta["tuning"]["calibration"]``), persisted per backend in
the cache, and used to price subsequent programs.

ISSUE 10 additions — multi-objective selection and learned cold start:

*Three objectives* — every candidate is scored on measured/predicted
seconds, modeled joules (``energy_j``: PCIe/HBM/ICI bytes × per-byte
constants + flops × ``flop_j``) and peak device bytes
(``peak_bytes``: the static residency walk in ``core.residency``,
moved by donation and kernel tile size).  The non-dominated surface is
returned in ``meta["tuning"]["pareto"]`` with per-objective winners in
``["winners"]``; ``tune(..., objective=)`` — and therefore
``plan(p, policy="auto", objective=)`` — selects which axis the chosen
plan minimizes ("time" | "energy" | "memory" | a weight mapping).

*Cross-program predictor* — measured candidate rows accumulate in the
tunecache per DEVICE CLASS; with rows from ≥ 2 other programs a
featurized linear model (``fit_candidate_predictor``) prices a
never-measured program's grid, gated by the same
rank-correlation-no-regression rule as the calibration and recorded in
``meta["tuning"]["predictor"]``.

Entry point: ``tune(program, backend=...)``, or equivalently
``plan(program, policy="auto", backend=...)``.

Candidates that fail the pipeline's ``SimulateFixPass`` (an invalid
placement) are recorded with ``valid=False`` and are never ranked or
measured — the explorer only ever returns a simulator-approved plan.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..roofline.analysis import (HW, candidate_features, dot_flops,
                                 fit_candidate_predictor,
                                 fit_offload_constants, kernel_roofline_terms,
                                 offload_cost_terms, parse_hlo,
                                 predict_candidate_s, rank_correlation)
from .analysis import ProgramAnalysis, analyze
from .backend import Backend, get_backend
from .ir import (AdvancedLoad, BlockKind, DelegateStore, Plan, Program,
                 Synchronize)
from .passes import Pipeline
from .residency import plan_peak_device_bytes
from .tunecache import (TuneCache, backend_fingerprint, default_cache,
                        device_class_key, grid_fingerprint,
                        program_fingerprint, tuning_fingerprint)
from .verify import PlanVerificationError, verify_plan

__all__ = ["PlanConfig", "enumerate_configs", "predict_cost", "tune",
           "winner_exec_kwargs", "pareto_front", "OBJECTIVES"]

# one kernel's tile choice: (kernel_name, ((param, value), ...)) — the
# params half is KernelVariant.params (canonical sorted pairs)
KernelChoice = Tuple[str, Tuple[Tuple[str, int], ...]]


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """One point of the plan space."""
    policy: str = "optimized"
    n_streams: int = 2
    fuse_loops: bool = True
    donate: bool = False
    # per-kernel tile choice, sorted by kernel name; () = registry
    # defaults (also the only value for kernel-free programs, keeping
    # labels/fingerprints of the pre-kernel-axis grid unchanged)
    kernel_variants: Tuple[KernelChoice, ...] = ()
    # mesh placement policy (ISSUE 9): "" on single-device backends
    # (keeping their labels/fingerprints unchanged), else one of
    # ``distributed.mesh_backend.DEFAULT_PLACEMENTS``
    mesh_placement: str = ""

    @property
    def label(self) -> str:
        base = (f"{self.policy}/streams{self.n_streams}"
                f"/{'fuse' if self.fuse_loops else 'nofuse'}"
                f"/{'donate' if self.donate else 'nodonate'}")
        if self.kernel_variants:
            kv = "+".join(
                f"{k}[{','.join(f'{n}={v}' for n, v in params)}]"
                for k, params in self.kernel_variants)
            base += "/" + kv
        if self.mesh_placement:
            base += "/" + self.mesh_placement
        return base

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        # JSON-stable form: a cache-hit table must compare equal to the
        # fresh run that stored it, so serialize the variant tuples the
        # way json will echo them back (nested lists)
        d["kernel_variants"] = [[k, [list(p) for p in params]]
                                for k, params in self.kernel_variants]
        return d

    def variants_map(self) -> Dict[str, Dict[str, int]]:
        """{kernel: {param: value}} view (what ``execute`` consumes)."""
        return {k: dict(params) for k, params in self.kernel_variants}


def _cfg_from_dict(d: Dict[str, Any]) -> PlanConfig:
    """Rebuild a PlanConfig from ``as_dict()`` output, including after a
    JSON round-trip (which turns the kernel_variants tuples into lists —
    unhashable in a frozen dataclass)."""
    d = dict(d)
    kv = d.get("kernel_variants") or ()
    d["kernel_variants"] = tuple(
        (str(k), tuple((str(n), int(v)) for n, v in params))
        for k, params in kv)
    return PlanConfig(**d)


DEFAULT_POLICIES: Tuple[str, ...] = ("naive", "optimized", "grouped",
                                     "pipeline")
DEFAULT_STREAMS: Tuple[int, ...] = (1, 2, 3, 4)

# the hw constants snapshotted into plan.meta["tuning"]["hw"]
_HW_KEYS = ("pcie_bw", "hbm_bw", "peak_flops_bf16", "ici_bw",
            "launch_overhead_s", "sync_overhead_s",
            "pcie_j_per_byte", "hbm_j_per_byte", "ici_j_per_byte", "flop_j")

# every field predict_cost() contributes to a candidate record (what an
# alias copies from its execution-class survivor).  energy_j / analytic_s
# / peak_bytes are the ISSUE-10 objective columns: class-level quantities
# (an alias executes identically), so aliases inherit them too.
_COST_FIELDS = ("h2d_bytes", "d2h_bytes", "loads", "stores", "syncs",
                "kernel_launches", "dispatches", "flops", "kernel_bytes",
                "coll_bytes", "transfer_s", "dispatch_s", "kernel_s",
                "collective_s", "predicted_s", "energy_j", "analytic_s",
                "peak_bytes")

# measurement-derived fields an alias inherits beside measured_s
_MEASURE_FIELDS = ("measured_kernel_s", "kernel_residual_s")


def enumerate_configs(policies: Sequence[str] = DEFAULT_POLICIES,
                      streams: Sequence[int] = DEFAULT_STREAMS,
                      fuse: Sequence[bool] = (True, False),
                      donate: Sequence[bool] = (False, True),
                      placements: Sequence[str] = ("",)
                      ) -> List[PlanConfig]:
    return [PlanConfig(policy=p, n_streams=s, fuse_loops=f, donate=d,
                       mesh_placement=mp)
            for p, s, f, d, mp in itertools.product(policies, streams,
                                                    fuse, donate,
                                                    placements)]


# --------------------------------------------------------------------------
# Static cost model.
# --------------------------------------------------------------------------

def _block_flops(program: Program,
                 shapes: Dict[str, Any]) -> Dict[int, float]:
    """Per-offload-block FLOPs via the roofline HLO machinery: lower each
    block BODY in isolation, parse its optimized HLO, count dot FLOPs —
    so every block is priced with its OWN flops, never the whole
    program's (pricing each block with program-level dot flops would
    double-count kernel_s across blocks).  Kernel-tagged blocks are
    skipped (0.0): they are priced analytically per tile variant via
    ``kernel_roofline_terms``, and lowering a Pallas call in interpret
    mode is both slow and uncountable here.  Falls back to 0 for bodies
    that fail to lower (the cost model then ranks on transfer + dispatch
    terms alone, which are the plan-dependent ones anyway)."""
    out: Dict[int, float] = {}
    try:
        import jax
        import jax.numpy as jnp
    except Exception:            # pragma: no cover - jax is baked in
        return {b.idx: 0.0 for b in program.offload_blocks()}
    for blk in program.offload_blocks():
        if blk.kernel:
            out[blk.idx] = 0.0
            continue
        avals = [shapes[v] for v in blk.reads]

        def wrapped(*arrays, _blk=blk):
            o = _blk.fn(jnp, **dict(zip(_blk.reads, arrays)))
            return tuple(o[w] for w in _blk.writes)

        try:
            txt = jax.jit(wrapped).lower(*avals).compile().as_text()
            out[blk.idx] = dot_flops(parse_hlo(txt))
        except Exception:
            out[blk.idx] = 0.0
    return out


def _kernel_block_terms(blk, params, shapes,
                        hw) -> Optional[Dict[str, float]]:
    """Analytic (flops, kernel_bytes) for a kernel-tagged block priced at
    tile choice ``params`` (None → the registry defaults) on the block's
    declared-read operand shapes.  None when the registry cannot price it
    (unknown kernel, missing shapes, invalid tile) — the caller then
    falls back to the generic HLO/nbytes pricing."""
    import numpy as np
    try:
        sds = [shapes[v] for v in blk.reads]
        op_shapes = [tuple(s.shape) for s in sds]
        itemsizes = [int(np.dtype(s.dtype).itemsize) for s in sds]
        if params is None:
            from repro.kernels.variants import KERNELS
            params = KERNELS[blk.kernel]["defaults"]
        return kernel_roofline_terms(blk.kernel, dict(params), op_shapes,
                                     itemsizes, hw=hw)
    except Exception:
        return None


def predict_cost(pl: Plan, cfg: PlanConfig,
                 block_flops: Optional[Dict[int, float]] = None,
                 hw: Optional[Dict[str, float]] = None,
                 shapes: Optional[Dict[str, Any]] = None,
                 mesh: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Walk the plan with loop-trip multipliers and price it:

    * transfer bytes  — Σ nbytes(var) × trip multiplier per load/store,
    * dispatches      — physical launches: per-iteration blocks and
      transfers, but a fusable pure-device loop nest counts ONCE per
      entry when ``cfg.fuse_loops`` (the whole-loop lowering's
      amortization, mirroring the compiler's structural eligibility),
    * kernel terms    — logical block launches × per-block flops and
      touched bytes.  A kernel-tagged block is priced analytically per
      tile variant (``cfg.kernel_variants`` via
      ``kernel_roofline_terms``, needs ``shapes``) so kernel_s differs
      across kernel-axis candidates; other blocks use their own HLO dot
      FLOPs (``block_flops``) + env nbytes.

    ``hw`` overrides the pricing constants (the tuner passes the
    calibrated set when one is cached for the backend); ``shapes`` is
    the analyzer's var → ShapeDtypeStruct map.  ``mesh`` is one
    placement's pricing context (``mesh_cost_terms``): per-device block
    FLOPs replace the single-device ones, each load's bytes scale by the
    variable's h2d factor (a replicated upload copies to every device),
    and the blocks' collective wire bytes accumulate into ``coll_bytes``
    priced against ``ici_bw``.  Returns the counters plus
    ``offload_cost_terms`` (transfer_s / dispatch_s / kernel_s /
    collective_s / predicted_s).
    """
    from .compile import fusable_loops
    program = pl.program
    nb = pl.meta.get("var_nbytes", {})
    flops_of = block_flops or {}
    kv_map = cfg.variants_map()
    pure = fusable_loops(pl) if cfg.fuse_loops else set()

    h2d_bytes = d2h_bytes = 0
    loads = stores = syncs = 0
    kernel_launches = 0          # logical
    dispatches = 0.0             # physical (fused nests count once)
    flops = 0.0
    kernel_bytes = 0.0
    coll_bytes = 0.0
    mesh_flops = (mesh or {}).get("flops_by_block", {})
    mesh_coll = (mesh or {}).get("coll_by_block", {})
    h2d_factor = (mesh or {}).get("h2d_factor", {})
    n_dev = (mesh or {}).get("n_devices", 1)

    mult_stack: List[int] = []
    fused_depth = 0

    def mult() -> int:
        m = 1
        for n in mult_stack:
            m *= n
        return m

    for op in pl.ops:
        if op.kind == "loop_begin":
            if fused_depth or op.loop_id in pure:
                if fused_depth == 0:
                    # one launch per entry of the nest — times the trip
                    # count of any enclosing UNFUSED loops (a pure inner
                    # loop under an impure outer re-launches per outer
                    # iteration; mult_stack has not pushed this loop yet)
                    dispatches += mult()
                fused_depth += 1
            mult_stack.append(program.loops[op.loop_id].n_iters)
        elif op.kind == "loop_end":
            mult_stack.pop()
            if fused_depth:
                fused_depth -= 1
        elif op.kind == "block":
            blk = program.blocks[op.block_idx]
            if blk.kind is not BlockKind.OFFLOAD:
                continue
            m = mult()
            kernel_launches += m
            if fused_depth == 0:
                dispatches += m
            kterms = None
            if blk.kernel and shapes is not None:
                kterms = _kernel_block_terms(blk, kv_map.get(blk.kernel),
                                             shapes, hw)
            if kterms is not None:
                flops += kterms["flops"] * m
                kernel_bytes += kterms["kernel_bytes"] * m
            else:
                flops += mesh_flops.get(blk.idx,
                                        flops_of.get(blk.idx, 0.0)) * m
                touched = set(blk.effective_reads()) | set(blk.writes)
                kernel_bytes += sum(nb.get(v, 0) for v in touched) * m
            coll_bytes += mesh_coll.get(blk.idx, 0.0) * m
        elif op.kind == "directive":
            d = op.directive
            m = mult()
            if isinstance(d, AdvancedLoad):
                loads += m
                h2d_bytes += nb.get(d.var, 0) * h2d_factor.get(d.var,
                                                               n_dev) * m
                dispatches += m
            elif isinstance(d, DelegateStore):
                stores += m
                d2h_bytes += nb.get(d.var, 0) * m
                dispatches += m
            elif isinstance(d, Synchronize):
                syncs += m

    terms = offload_cost_terms(h2d_bytes, d2h_bytes, dispatches, syncs,
                               flops, kernel_bytes, coll_bytes, hw=hw)
    return {
        "h2d_bytes": int(h2d_bytes), "d2h_bytes": int(d2h_bytes),
        "loads": int(loads), "stores": int(stores), "syncs": int(syncs),
        "kernel_launches": int(kernel_launches),
        "dispatches": float(dispatches), "flops": float(flops),
        "kernel_bytes": float(kernel_bytes),
        "coll_bytes": float(coll_bytes), **terms,
    }


# --------------------------------------------------------------------------
# Multi-objective selection (ISSUE 10): time × energy × memory.
# --------------------------------------------------------------------------

OBJECTIVES: Tuple[str, ...] = ("time", "energy", "memory")

# lexicographic tie-break order per primary objective: a winner must sit
# on the Pareto frontier, and the lexicographic minimum always does
_LEXI_ORDER = {"time": ("time", "energy", "memory"),
               "energy": ("energy", "time", "memory"),
               "memory": ("memory", "time", "energy")}


def pareto_front(points: Sequence[Sequence[float]]) -> List[int]:
    """Indices of the non-dominated points (minimization, every axis).
    ``a`` dominates ``b`` iff a ≤ b on all axes and a < b on at least
    one; duplicated points are all kept (neither dominates)."""
    pts = [tuple(float(v) for v in p) for p in points]
    front = []
    for i, a in enumerate(pts):
        dominated = False
        for j, b in enumerate(pts):
            if j != i and all(bv <= av for bv, av in zip(b, a)) \
                    and any(bv < av for bv, av in zip(b, a)):
                dominated = True
                break
        if not dominated:
            front.append(i)
    return front


def _objective_value(r: Dict[str, Any], obj: str) -> float:
    """One candidate record's score on one objective.  Time prefers the
    measurement; an unmeasured table falls back to the analytic
    prediction (``predictor_s``, when a cold-start model priced the
    grid, is recorded beside it but never silently replaces the
    objective column — see ``used_for_ranking``)."""
    if obj == "time":
        m = r.get("measured_s")
        return float(m if m is not None else r.get("predicted_s", 0.0))
    if obj == "energy":
        return float(r.get("energy_j", 0.0) or 0.0)
    if obj == "memory":
        return float(r.get("peak_bytes", 0.0) or 0.0)
    raise ValueError(f"unknown objective {obj!r}")


def _objective_pool(cands: Sequence[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Records the frontier/winners are computed over: the valid class
    survivors (aliases are the same execution — duplicate points), the
    measured ones when any measurement happened."""
    survivors = [r for r in cands
                 if r.get("valid") and r.get("alias_of") is None]
    measured = [r for r in survivors if r.get("measured_s") is not None]
    return measured or survivors


def _pareto_records(cands: Sequence[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """``meta["tuning"]["pareto"]``: the non-dominated surface of the
    candidate table as (label, time_s, energy_j, peak_bytes) points,
    sorted fastest-first.  Coordinate-identical survivors (distinct
    policies whose plans happen to price the same) collapse to one point
    — the best-ranked label — so the surface stays readable."""
    pool = _objective_pool(cands)
    pts = [tuple(_objective_value(r, o) for o in OBJECTIVES) for r in pool]
    best_at: Dict[Tuple[float, ...], Dict[str, Any]] = {}
    for i in pareto_front(pts):
        seen = best_at.get(pts[i])
        if seen is None or (pool[i].get("rank") or 0) < (seen.get("rank")
                                                         or 0):
            best_at[pts[i]] = pool[i]
    front = [{"label": r["label"], "time_s": pt[0], "energy_j": pt[1],
              "peak_bytes": pt[2]} for pt, r in best_at.items()]
    front.sort(key=lambda e: (e["time_s"], e["label"]))
    return front


def _objective_winners(cands: Sequence[Dict[str, Any]]) -> Dict[str, str]:
    """Per-objective winner labels.  Each is the LEXICOGRAPHIC minimum
    (primary objective, then the others, then predicted rank), which is
    provably on the Pareto frontier — a plain per-axis argmin could pick
    a dominated point on a tie."""
    pool = _objective_pool(cands)
    winners = {}
    for obj in OBJECTIVES:
        order = _LEXI_ORDER[obj]
        winners[obj] = min(
            pool, key=lambda r: tuple(_objective_value(r, o) for o in order)
            + (r.get("rank") or 0,))["label"]
    return winners


def _check_objective(objective: Any) -> Any:
    """Validate/normalize the ``objective=`` argument: one of
    ``OBJECTIVES`` or a non-empty {objective: weight} mapping."""
    if isinstance(objective, str):
        if objective not in OBJECTIVES:
            raise ValueError(
                f"objective must be one of {OBJECTIVES} or a weight "
                f"mapping, got {objective!r}")
        return objective
    if isinstance(objective, dict):
        bad = set(objective) - set(OBJECTIVES)
        if bad or not objective:
            raise ValueError(
                f"objective weight keys must be among {OBJECTIVES}, "
                f"got {sorted(objective)}")
        return {k: float(v) for k, v in objective.items()}
    raise ValueError(f"unsupported objective {objective!r}")


def _weighted_choice(cands: Sequence[Dict[str, Any]],
                     weights: Dict[str, float]) -> Dict[str, Any]:
    """Scalarized selection: each objective min-normalized over the pool
    (so weights compare dimensionless ratios-to-best, not seconds against
    joules), then the weighted sum is minimized."""
    pool = _objective_pool(cands)
    mins = {o: min(_objective_value(r, o) for r in pool) or 1.0
            for o in OBJECTIVES}

    def score(r):
        return sum(w * _objective_value(r, o) / mins[o]
                   for o, w in weights.items())
    return min(pool, key=lambda r: (score(r), r.get("rank") or 0))


def _select_chosen(cands: Sequence[Dict[str, Any]], objective: Any,
                   winners: Dict[str, str]) -> Dict[str, Any]:
    """The chosen record for a non-default objective (``"time"`` keeps
    the tuner's historical rule and never routes through here)."""
    if isinstance(objective, dict):
        return _weighted_choice(cands, objective)
    label = winners[objective]
    return next(r for r in cands if r["label"] == label)


# --------------------------------------------------------------------------
# Measurement.
# --------------------------------------------------------------------------

def _donation_variant(be: Backend, donate: bool) -> Backend:
    """``be`` with donation switched to ``donate`` (a memoized twin when
    they differ, in EITHER direction — a donate=True backend passed by
    the caller must not leak donation into nodonate candidates).
    Backends without a donation concept measure both as themselves."""
    return be.variant(donate=donate)


def _measurable(program: Program) -> bool:
    return all(type(v).__name__ != "ShapeDtypeStruct"
               for v in program.inputs.values())


def _measure(pl: Plan, cfg: PlanConfig, be: Backend, reps: int,
             placement: Any = None) -> Tuple[float, float]:
    from .executor import execute
    # measure on a physically matching backend: cfg.n_streams real
    # queues (streams 3/4 must not fold onto a 2-queue instance), the
    # candidate's donation flag, and — on a mesh backend — the
    # candidate's per-variable placement twin; launching the candidate's
    # kernel tile sizes.  Returns (wall_time, kernel_time) of the best
    # rep: the kernel leg feeds the measured-vs-predicted residual that
    # makes roofline drift visible in the tuning table.
    mbe = be.variant(n_streams=cfg.n_streams, donate=cfg.donate)
    if placement is not None and hasattr(mbe, "with_placement"):
        mbe = mbe.with_placement(placement)
    kw = dict(mode="compiled", fuse_loops=cfg.fuse_loops,
              kernel_variants=cfg.variants_map() or None,
              backend=mbe)
    execute(pl, **kw)                       # warm jits + plan lowering
    best = float("inf")
    best_kernel = 0.0
    for _ in range(max(1, reps)):
        _, s = execute(pl, **kw)
        if s.wall_time < best:              # steady-state, compile excluded
            best = s.wall_time
            best_kernel = s.kernel_time
    return best, best_kernel


def winner_exec_kwargs(pl: Plan, backend: Any = None) -> Dict[str, Any]:
    """``execute()`` kwargs that honor a tuned plan's chosen variant:
    compiled mode with the winner's fusion flag and kernel tile sizes,
    on a donate-enabled twin of ``backend`` when the winner wants
    donation.  Without this a caller re-running the winner on the plain
    backend measures the nodonate timing under a donate label.  The
    flags come from the plan's CHOSEN candidate, so tuning with
    ``objective="energy"``/``"memory"`` flows through here unchanged —
    the executor simply gets that objective's winner."""
    be = _donation_variant(get_backend(backend),
                           bool(pl.meta.get("donate")))
    return dict(mode="compiled",
                fuse_loops=bool(pl.meta.get("fuse_loops", True)),
                kernel_variants=pl.meta.get("kernel_variants") or None,
                backend=be)


# --------------------------------------------------------------------------
# Calibration.
# --------------------------------------------------------------------------

def _calibrate(rows: List[Dict[str, Any]],
               pricing_hw: Dict[str, float]) -> Dict[str, Any]:
    """Fit the offload constants from the measured class survivors and
    judge the fit by predicted-vs-measured rank correlation.  The fit is
    ``accepted`` only when it does not lower the correlation on the
    observed table — a declined calibration is still recorded (both
    correlations), it just isn't persisted or used for pricing."""
    before = rank_correlation([r["predicted_s"] for r in rows],
                              [r["measured_s"] for r in rows])
    record = {"n_rows": len(rows), "fitted": None, "accepted": False,
              "rank_corr_before": before, "rank_corr_after": None}
    fitted = fit_offload_constants(rows, hw=pricing_hw)
    if fitted is None:
        return record
    hw2 = dict(pricing_hw)
    hw2.update(fitted)
    for r in rows:
        r["calibrated_s"] = offload_cost_terms(
            r["h2d_bytes"], r["d2h_bytes"], r["dispatches"], r["syncs"],
            r["flops"], r["kernel_bytes"], r.get("coll_bytes", 0.0),
            hw=hw2)["predicted_s"]
    after = rank_correlation([r["calibrated_s"] for r in rows],
                             [r["measured_s"] for r in rows])
    record.update(fitted=fitted, rank_corr_after=after,
                  accepted=after >= before)
    return record


# --------------------------------------------------------------------------
# The explorer.
# --------------------------------------------------------------------------

def _resolve_cache(cache: Any) -> Optional[TuneCache]:
    if cache is None:
        return default_cache()
    if cache is False:
        return None
    return cache


def _cached_plan(program: Program, an: ProgramAnalysis, tuning: Dict,
                 fp: str, tc: TuneCache, be: Backend,
                 objective: Any = "time") -> Plan:
    """Rebuild the winning plan from a cache hit: the pass pipeline is
    deterministic, so re-running it for the chosen config reproduces the
    measured winner's ops exactly; the serialized table is attached
    verbatim (identical to the fresh run that stored it).

    The requested ``objective`` is NOT part of the fingerprint — the
    measured table is objective-independent, so one entry answers every
    objective.  A request that differs from the stored selection
    re-selects the chosen label from the stored per-objective winners
    (or re-scalarizes, for weight mappings) without re-measuring.

    The rebuilt winner is re-vetted by the static verifier — a corrupt
    payload (malformed keys raise ``KeyError``/``StopIteration`` here)
    or a stale one that no longer verifies against the current pipeline
    raises, and the caller evicts the entry instead of executing it."""
    if objective != tuning.get("objective", "time"):
        tuning = dict(tuning)
        tuning["objective"] = objective
        if objective == "time":
            measured = [r for r in tuning["candidates"]
                        if r.get("valid") and r.get("measured_s") is not None]
            tuning["chosen"] = (
                min(measured,
                    key=lambda r: (r["measured_s"], r.get("rank") or 0))
                if measured else tuning["candidates"][0])["label"]
        else:
            tuning["chosen"] = _select_chosen(
                tuning["candidates"], objective,
                tuning.get("winners") or {})["label"]
    chosen = next(c for c in tuning["candidates"]
                  if c["label"] == tuning["chosen"])
    cfg = _cfg_from_dict(chosen["config"])
    pl = Pipeline.default(cfg.policy, n_streams=cfg.n_streams
                          ).run(program, analysis=an)
    mesh_rec = tuning.get("mesh")
    report = verify_plan(pl, donate=cfg.donate and be.supports_donation,
                         kernel_variants=cfg.variants_map() or None,
                         shapes=an.shapes, mesh=mesh_rec)
    pl.meta["verify"] = report.meta_record()
    report.raise_if_failed()
    pl.meta["tuning"] = tuning
    if mesh_rec is not None:
        pl.meta["mesh"] = mesh_rec
    pl.meta["fuse_loops"] = cfg.fuse_loops
    pl.meta["donate"] = cfg.donate
    pl.meta["kernel_variants"] = cfg.variants_map()
    pl.meta["optimize"] = cfg.policy != "naive"
    pl.meta["tuning_cache"] = {"hit": True, "measurements": 0,
                               "path": str(tc.path), "fingerprint": fp}
    return pl


def _mesh_record(be: Backend, ctx: Dict[str, Any]) -> Dict[str, Any]:
    """JSON-safe ``meta["mesh"]`` record for one placement context (what
    the verifier checks, ``execute()`` re-applies via ``with_placement``,
    and the tunecache round-trips)."""
    shape, axes = be.mesh_desc
    return {
        "shape": list(shape),
        "axes": list(axes),
        "placement": ctx["placement"],
        "n_devices": int(ctx["n_devices"]),
        "specs": {v: list(e) for v, e in ctx["specs"].items()},
        "dropped": [list(d) for d in ctx["dropped"]],
    }


def _kernel_variant_combos(program: Program,
                           an: ProgramAnalysis) -> List[Tuple]:
    """The kernel axis of the grid: the cross product of tile variants
    over the program's kernel-tagged blocks (blocks sharing a kernel name
    share the choice).  ``[()]`` for kernel-free programs, keeping their
    grid exactly the pre-kernel-axis one."""
    import numpy as np
    kernels: Dict[str, Any] = {}
    for blk in program.offload_blocks():
        if blk.kernel and blk.kernel not in kernels:
            kernels[blk.kernel] = blk
    if not kernels:
        return [()]
    from repro.kernels.variants import variants_for
    per_kernel = []
    for name in sorted(kernels):
        blk = kernels[name]
        try:
            sds = [an.shapes[v] for v in blk.reads]
            shapes = [tuple(s.shape) for s in sds]
            itemsizes = [int(np.dtype(s.dtype).itemsize) for s in sds]
            vs = variants_for(name, shapes, itemsizes)
        except Exception:
            vs = ()
        if vs:
            per_kernel.append([(name, v.params) for v in vs])
    if not per_kernel:
        return [()]
    return [tuple(combo) for combo in itertools.product(*per_kernel)]


def tune(program: Program, *, backend: Any = None,
         analysis: Optional[ProgramAnalysis] = None,
         policies: Sequence[str] = DEFAULT_POLICIES,
         streams: Sequence[int] = DEFAULT_STREAMS,
         fuse: Sequence[bool] = (True, False),
         donate: Sequence[bool] = (False, True),
         placements: Optional[Sequence[str]] = None,
         configs: Optional[Sequence[PlanConfig]] = None,
         measure: bool = True, top_k: Optional[int] = None,
         reps: int = 2, cache: Any = None, refresh: bool = False,
         calibrate: bool = True, use_calibration: bool = True,
         objective: Any = "time") -> Plan:
    """Explore the plan space; return the winning ``Plan``.

    Candidates are grouped into *execution classes* (identical ops +
    effective fusion + effective donation): each class is priced and
    measured once through its first-enumerated survivor, and the merged
    configs appear in the table with ``alias_of`` pointing at it — the
    table still enumerates the full config grid the paper explores,
    measurement cost scales with the DISTINCT executions.  Measured
    classes run ``reps`` times compiled on a physically matching
    ``backend.variant`` (all of them, or only the predicted
    top-``top_k`` classes).  The winner is the best *measured* candidate
    (predicted order breaks ties / decides when measurement is off).

    ``cache`` is a ``TuneCache`` (None → the ``REPRO_TUNE_CACHE``
    default, False → disabled): when the content fingerprint of
    (program, backend, grid, protocol, cost-model version) hits, the
    stored winner + table are returned with ZERO measurements;
    ``refresh=True`` re-measures and overwrites.  ``measure=False``
    bypasses the cache entirely (predictions are cheap and a cached
    measured table would not be the requested artifact).

    ``calibrate``/``use_calibration`` control the measured calibration:
    fitted ``pcie_bw``/``launch_overhead_s``/``sync_overhead_s`` are
    stored per DEVICE CLASS (``tunecache.device_class_key`` — shared
    across stream-count/donation twins of the same device) and used to
    price subsequent tuning calls (see ``meta["tuning"]["calibration"]``
    for the fit and the before/after rank correlations).

    ``objective`` (ISSUE 10) selects which axis the winner minimizes:
    ``"time"`` (default, the historical behaviour), ``"energy"``
    (modeled joules: transfer + HBM + interconnect bytes × per-byte
    constants, flops × ``flop_j``), ``"memory"`` (peak device bytes from
    the static residency walk, ``plan_peak_device_bytes`` — donation and
    kernel tile size both move it), or a ``{objective: weight}`` mapping
    scalarized over min-normalized columns.  Every candidate carries all
    three columns and the non-dominated surface is returned regardless
    of the objective, so switching objectives re-selects from the same
    (cached) table without re-measuring.

    When the tunecache holds measured rows from ≥ 2 OTHER programs of
    the same device class, a cross-program predictor
    (``fit_candidate_predictor``) prices this grid too
    (``predictor_s``): accepted — and persisted — only when it does not
    lower the predicted-vs-measured rank correlation against the
    uncalibrated analytic model on this program's measurements; on a
    zero-measurement cold start (``measure=False`` or abstract inputs)
    an available model picks the winner (``used_for_ranking``).

    Returned meta:

        plan.meta["tuning"]   {"chosen", "objective", "backend", "hw",
                              "calibration", "predictor", "winners",
                              "pareto", "candidates"} — candidates
                              ranked by predicted cost, each with
                              predicted AND measured seconds plus the
                              energy_j / peak_bytes objective columns
        plan.meta["tuning_cache"]
                              {"hit", "measurements", "path",
                              "fingerprint"} — cache outcome + how many
                              configs were actually measured
        plan.meta["fuse_loops"] / ["donate"]
                              — how the winner wants to be executed
    """
    from .compile import fusable_loops
    an = analysis or analyze(program)
    be = get_backend(backend)
    # -- mesh placement axis (ISSUE 9): only on placement-capable backends --
    mesh_capable = (hasattr(be, "with_placement")
                    and getattr(be, "mesh_desc", None) is not None)
    if placements is None:
        if mesh_capable:
            from repro.distributed.mesh_backend import DEFAULT_PLACEMENTS
            placements = DEFAULT_PLACEMENTS
        else:
            placements = ("",)   # single-device grid: unchanged labels/fps
    cfg_list = list(configs) if configs is not None else enumerate_configs(
        policies, streams, fuse, donate, placements)
    if not cfg_list:
        raise ValueError("tune() needs at least one candidate config")

    # per-placement pricing context: specs through the divisibility-
    # guarded sharding rules, per-device flops + collective wire bytes
    # off the post-SPMD HLO, PCIe replication factors
    mesh_ctx: Dict[str, Dict[str, Any]] = {}
    if mesh_capable:
        from repro.distributed.mesh_backend import (mesh_cost_terms,
                                                    placement_specs)
        for pol in sorted({c.mesh_placement for c in cfg_list
                           if c.mesh_placement}):
            specs, dropped = placement_specs(an.shapes, be.mesh, pol)
            ctx = mesh_cost_terms(program, an.shapes, be, specs)
            ctx["placement"] = pol
            ctx["dropped"] = dropped
            mesh_ctx[pol] = ctx

    # -- kernel axis: cross the grid with per-kernel tile variants ----------
    combos = _kernel_variant_combos(program, an)
    if combos != [()]:
        expanded: List[PlanConfig] = []
        for cfg in cfg_list:
            if cfg.kernel_variants:
                expanded.append(cfg)       # caller pinned a tile choice
            else:
                expanded.extend(
                    dataclasses.replace(cfg, kernel_variants=c)
                    for c in combos)
        cfg_list = expanded

    objective = _check_objective(objective)

    # -- cache: the measured-table slot is measure-only, but the device-
    # class store (calibration / measured rows / predictor) also serves
    # prediction-only runs — that is the whole point of a cold start
    tc = _resolve_cache(cache)
    fp = slot = None
    be_key = backend_fingerprint(be)
    dc_key = device_class_key(be)
    prog_fp = program_fingerprint(program)
    if tc is not None and measure:
        protocol = {"measure": True, "top_k": top_k, "reps": int(reps),
                    "calibrate": bool(calibrate),
                    "use_calibration": bool(use_calibration)}
        fp = tuning_fingerprint(program, be, cfg_list, protocol, HW)
        # the grid/protocol is part of the SLOT (coexisting entries),
        # not just the fingerprint (which would evict-thrash between
        # alternating protocol variants of the same program); the
        # OBJECTIVE is deliberately absent from both — the table is
        # objective-independent and re-selection is free
        slot = (f"{program.name}--{be_key}"
                f"--{grid_fingerprint(cfg_list, protocol)[:16]}")
        if not refresh:
            payload = tc.lookup(slot, fp)
            if payload is not None:
                try:
                    return _cached_plan(program, an, payload["tuning"],
                                        fp, tc, be, objective)
                except (PlanVerificationError, KeyError, StopIteration,
                        TypeError, ValueError):
                    # corrupt payload or a winner that no longer passes
                    # the verifier: evict and fall through to a fresh run
                    tc.evict(slot)

    # -- pricing constants: calibrated when a fit is cached -----------------
    pricing_hw = dict(HW)
    if use_calibration and tc is not None:
        fitted = tc.load_calibration(dc_key, HW)
        if fitted:
            pricing_hw.update(fitted)

    # -- cross-program cold-start predictor (ISSUE 10): fit from OTHER
    # programs' measured rows accumulated for this device class; fall
    # back to the last persisted (previously accepted) model
    predictor_model = None
    predictor_source = None
    n_train_rows = 0
    if tc is not None:
        train_rows = tc.load_measured_rows(dc_key, HW, exclude_fp=prog_fp)
        n_train_rows = len(train_rows)
        predictor_model = fit_candidate_predictor(train_rows)
        if predictor_model is not None:
            predictor_source = "fit"
        else:
            predictor_model = tc.load_predictor(dc_key, HW)
            if predictor_model is not None:
                predictor_source = "cache"

    # -- enumerate + dominance-prune into execution classes -----------------
    flops_cache: Optional[Dict[int, float]] = None
    records: List[Dict[str, Any]] = []
    plans: Dict[str, Plan] = {}
    classes: Dict[Tuple, Dict[str, Any]] = {}
    # the pipeline is deterministic in (policy, n_streams): kernel-axis
    # expansion re-visits each placement many times, so memoize the runs
    pipe_cache: Dict[Tuple[str, int], Plan] = {}

    for cfg in cfg_list:
        base = {"label": cfg.label, "config": cfg.as_dict(),
                "aliases": [], "alias_of": None, "valid": True,
                "error": None, "measured_s": None, "calibrated_s": None,
                "rank": None}
        try:
            pipe_key = (cfg.policy, cfg.n_streams)
            pl = pipe_cache.get(pipe_key)
            if pl is None:
                pl = Pipeline.default(cfg.policy, n_streams=cfg.n_streams
                                      ).run(program, analysis=an)
                pipe_cache[pipe_key] = pl
        except (RuntimeError, ValueError) as e:
            base.update(valid=False, error=str(e))
            records.append(base)
            continue
        # execution class: the ops tuple itself (frozen dataclasses —
        # exact, unlike its hash) + the flags as the EXECUTOR sees them
        # + the kernel tile choice (already canonical: clamped/deduped by
        # the registry, so declared tiles that launch identically merged
        # during enumeration).  fuse without fusable loops, or donate on
        # a backend without donation, cannot change execution: such
        # configs merge here instead of being measured separately
        # (dominance pruning).
        eff_fuse = cfg.fuse_loops and bool(fusable_loops(pl))
        eff_donate = cfg.donate and be.supports_donation
        key = (tuple(pl.ops), eff_fuse, eff_donate, cfg.kernel_variants,
               cfg.mesh_placement)
        survivor = classes.get(key)
        cfg_mesh = mesh_ctx.get(cfg.mesh_placement)
        if survivor is None:
            # every execution class is statically vetted BEFORE it is
            # priced or measured: a candidate the verifier rejects is
            # recorded invalid (never ranked, never run) and counted in
            # meta["tuning"]["pruned_invalid"].  Verification depends
            # exactly on the class key (ops, donation, kernel tiles),
            # so aliases inherit the survivor's verdict.
            vrep = verify_plan(pl, donate=eff_donate,
                               kernel_variants=cfg.variants_map() or None,
                               shapes=an.shapes, collect_lints=False,
                               mesh=(_mesh_record(be, cfg_mesh)
                                     if cfg_mesh else None))
            if not vrep.ok:
                base.update(valid=False, error="verifier: " + "; ".join(
                    str(v) for v in vrep.errors[:3]))
                classes[key] = base
                records.append(base)
                continue
            if flops_cache is None:
                flops_cache = _block_flops(program, an.shapes)
            base.update(predict_cost(pl, cfg, flops_cache, hw=pricing_hw,
                                     shapes=an.shapes, mesh=cfg_mesh))
            # remaining objective columns (energy_j already arrived with
            # the cost terms): analytic_s re-prices the counters with the
            # DEFAULT constants — the predictor's anchor feature and the
            # no-regression baseline its acceptance is judged against —
            # and peak_bytes walks the plan's residency under this
            # class's donation flag and kernel tile choice
            base["analytic_s"] = offload_cost_terms(
                base["h2d_bytes"], base["d2h_bytes"], base["dispatches"],
                base["syncs"], base["flops"], base["kernel_bytes"],
                base["coll_bytes"])["predicted_s"]
            base["peak_bytes"] = plan_peak_device_bytes(
                pl, donate=eff_donate,
                kernel_variants=cfg.variants_map() or None,
                shapes=an.shapes)
            classes[key] = base
            plans[cfg.label] = pl
        else:
            survivor["aliases"].append(cfg.label)
            base["alias_of"] = survivor["label"]
            if not survivor["valid"]:
                base.update(valid=False, error=survivor["error"])
            else:
                base.update({k: survivor[k] for k in _COST_FIELDS})
        records.append(base)

    valid = [r for r in records if r["valid"]]
    if not valid:
        raise RuntimeError(
            "plan-space exploration found no valid candidate: "
            + "; ".join(f"{r['label']}: {r['error']}" for r in records))
    valid.sort(key=lambda r: r["predicted_s"])
    for i, r in enumerate(valid):
        r["rank"] = i + 1

    # price the grid with the cross-program model — per candidate,
    # aliases included: the stream count is a knob the analytic model
    # cannot always separate (classes merge when streams don't change
    # the ops), but it IS a predictor feature, so merged configs carry
    # distinct learned prices
    if predictor_model is not None:
        for r in valid:
            r["predictor_s"] = predict_candidate_s(predictor_model, r)

    # -- measure one survivor per class -------------------------------------
    n_measured = 0
    if measure and _measurable(program):
        survivors = [r for r in valid if r["alias_of"] is None]
        to_measure = (survivors if top_k is None
                      else survivors[:max(1, top_k)])
        for r in to_measure:
            cfg = _cfg_from_dict(r["config"])
            ctx = mesh_ctx.get(cfg.mesh_placement)
            wall, kern = _measure(plans[r["label"]], cfg, be, reps,
                                  placement=(ctx["specs"] if ctx else None))
            r["measured_s"] = wall
            # roofline drift per variant: measured kernel leg vs the
            # analytic kernel_s the ranking used (0 residual on backends
            # that don't time kernels, e.g. interpreted numpy)
            r["measured_kernel_s"] = kern
            r["kernel_residual_s"] = kern - r["kernel_s"]
            n_measured += 1

    # -- calibration (on the measured survivors, before alias fan-out) ------
    calibration = None
    measured_survivors = [r for r in valid if r["alias_of"] is None
                          and r["measured_s"] is not None]
    if calibrate and measured_survivors:
        calibration = _calibrate(measured_survivors, pricing_hw)
        if calibration["accepted"] and calibration["fitted"] and tc:
            tc.store_calibration(dc_key, HW, calibration["fitted"])

    # accumulate this program's measured rows into the device-class
    # store — the training set future programs' cold starts fit from.
    # Survivors only: an alias shares its survivor's measurement, and
    # labeling a different stream count with the same seconds would
    # teach the model the knob is free when it merely wasn't separable
    # here.
    if tc is not None and measured_survivors:
        tc.add_measured_rows(
            dc_key, HW, prog_fp, program.name,
            [dict(candidate_features(r), measured_s=r["measured_s"],
                  program=program.name)
             for r in measured_survivors])

    # predictor acceptance: same no-regression gate as the calibration —
    # kept (and persisted for true cold starts) only when its ranking of
    # THIS program's measured survivors is at least as good as the
    # uncalibrated analytic model's
    predictor = None
    if tc is not None:
        predictor = {"n_rows": n_train_rows,
                     "n_programs": (predictor_model or {}).get("n_programs"),
                     "source": predictor_source, "accepted": None,
                     "rank_corr_analytic": None,
                     "rank_corr_predictor": None,
                     "used_for_ranking": False}
        if predictor_model is not None and len(measured_survivors) >= 2:
            corr_a = rank_correlation(
                [r["analytic_s"] for r in measured_survivors],
                [r["measured_s"] for r in measured_survivors])
            corr_p = rank_correlation(
                [r["predictor_s"] for r in measured_survivors],
                [r["measured_s"] for r in measured_survivors])
            predictor.update(rank_corr_analytic=corr_a,
                             rank_corr_predictor=corr_p,
                             accepted=corr_p >= corr_a)
            if predictor["accepted"] and predictor_source == "fit":
                tc.store_predictor(dc_key, HW, predictor_model)

    # merged configs inherit their survivor's measurements
    by_label = {r["label"]: r for r in valid}
    for r in valid:
        if r["alias_of"] is not None:
            survivor = by_label[r["alias_of"]]
            r["measured_s"] = survivor["measured_s"]
            r["calibrated_s"] = survivor["calibrated_s"]
            for k in _MEASURE_FIELDS:
                if k in survivor:
                    r[k] = survivor[k]

    measured = [r for r in valid if r["measured_s"] is not None]
    winners = _objective_winners(valid)
    pareto = _pareto_records(valid)
    if objective == "time":
        # the historical rule: best measured seconds, ties (merged
        # classes share a value) resolve to the best rank, which is
        # always a class survivor.  On a zero-measurement cold start an
        # available cross-program model outranks the analytic order.
        if measured:
            chosen = min(measured,
                         key=lambda r: (r["measured_s"], r["rank"]))
        elif predictor_model is not None:
            chosen = min(valid,
                         key=lambda r: (r["predictor_s"], r["rank"]))
            predictor["used_for_ranking"] = True
        else:
            chosen = valid[0]
    else:
        chosen = _select_chosen(valid, objective, winners)

    chosen_cfg = _cfg_from_dict(chosen["config"])
    best = plans[chosen["alias_of"] or chosen["label"]]
    chosen_mesh = (
        _mesh_record(be, mesh_ctx[chosen_cfg.mesh_placement])
        if chosen_cfg.mesh_placement in mesh_ctx else None)
    best.meta["tuning"] = {
        "chosen": chosen["label"],
        "objective": objective,
        "winners": winners,
        "pareto": pareto,
        "backend": be.name,
        "hw": {k: pricing_hw[k] for k in _HW_KEYS},
        "calibration": calibration,
        "predictor": predictor,
        "kernel_variants": chosen_cfg.variants_map(),
        "mesh": chosen_mesh,
        "pruned_invalid": sum(
            1 for r in records
            if not r["valid"] and str(r["error"]).startswith("verifier:")),
        "candidates": valid + [r for r in records if not r["valid"]],
    }
    if chosen_mesh is not None:
        best.meta["mesh"] = chosen_mesh
    # the winner's full verdict (lints included) — the per-class vet
    # above ran error-only
    vrep = verify_plan(
        best, donate=chosen["config"]["donate"] and be.supports_donation,
        kernel_variants=chosen_cfg.variants_map() or None,
        shapes=an.shapes, mesh=chosen_mesh)
    best.meta["verify"] = vrep.meta_record()
    best.meta["fuse_loops"] = chosen["config"]["fuse_loops"]
    best.meta["donate"] = chosen["config"]["donate"]
    best.meta["kernel_variants"] = chosen_cfg.variants_map()
    best.meta["optimize"] = chosen["config"]["policy"] != "naive"
    best.meta["tuning_cache"] = {
        "hit": False, "measurements": n_measured,
        "path": str(tc.path) if tc is not None else None,
        "fingerprint": fp,
    }

    if tc is not None and n_measured:
        tc.store(slot, fp, {"tuning": best.meta["tuning"]})
    return best
