"""Pass-pipeline skeleton for the planner (ISSUE 4 tentpole).

The monolithic ``plan()`` is decomposed into independent passes over a
shared mutable ``PlanDraft``: linearize → placement policy →
simulate-and-fix → noupdate tagging → stream assignment → group
head/tail → purity marking.  Each pass reads and rewrites
``draft.ops``/``draft.meta`` only; the ``Pipeline`` runs them in order
and finalizes the draft into an immutable-ish ``Plan``.

The contract every pass honors:

* passes never touch ``draft.program`` or ``draft.analysis`` (read-only
  facts); mutable plan state lives in ``ops``, ``groups``/``group_of``
  and ``meta``;
* structural passes (linearize, noupdate, group head/tail) are no-ops
  when their postcondition already holds; placement passes expect the
  bare skeleton and may not be re-run on a placed draft;
* validity is owned by ``SimulateFixPass`` — any pipeline that includes
  it produces a plan the checking executor accepts, or raises.

This is what makes plan generation *enumerable*: the tuner
(``repro.core.tuner``) swaps the placement pass and re-parameterizes the
stream pass to sweep the plan space the paper explores by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..analysis import ProgramAnalysis, analyze
from ..ir import Plan, PlanOp, Program

__all__ = ["PlanDraft", "Pass", "Pipeline"]


@dataclasses.dataclass
class PlanDraft:
    """Shared mutable state the passes operate on.

    ``groups``/``group_of`` start as the analysis' connected-component
    grouping; a placement policy may rewrite them (e.g. the grouped
    policy folds every codelet into one group) and all downstream
    passes must read the draft's copy, never the analysis'.
    """
    program: Program
    analysis: ProgramAnalysis
    ops: List[PlanOp] = dataclasses.field(default_factory=list)
    groups: Dict[int, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    group_of: Dict[int, int] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_program(cls, program: Program,
                     analysis: Optional[ProgramAnalysis] = None
                     ) -> "PlanDraft":
        an = analysis or analyze(program)
        return cls(program=program, analysis=an,
                   groups=dict(an.groups), group_of=dict(an.group_of))

    def var_nbytes(self) -> Dict[str, int]:
        """Concrete byte size of every program variable (from the
        analysis' abstract shapes) — the cost model's raw material."""
        out = {}
        for v, sd in self.analysis.shapes.items():
            out[v] = int(np.prod(sd.shape, dtype=np.int64)
                         ) * np.dtype(sd.dtype).itemsize
        return out


class Pass:
    """One reorderable planner stage.  Subclasses override ``run``."""

    name: str = "pass"

    def run(self, draft: PlanDraft) -> None:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<{type(self).__name__}>"


class Pipeline:
    """Ordered pass list → ``Plan`` factory.

    >>> Pipeline.default("optimized").run(program)
    """

    def __init__(self, passes):
        self.passes = list(passes)

    @classmethod
    def default(cls, policy: str = "optimized", *,
                n_streams: int = 2) -> "Pipeline":
        # imported here so pass modules stay independently importable
        from .linearize import LinearizePass
        from .placement import GroupFinalizePass, get_placement
        from .purity import PurityPass
        from .simulate import NoupdatePass, SimulateFixPass
        from .streams import StreamAssignPass
        placement = get_placement(policy)()
        return cls([
            LinearizePass(),
            placement,
            SimulateFixPass(elide=placement.elide),
            NoupdatePass(),
            StreamAssignPass(n_streams=n_streams),
            GroupFinalizePass(),
            PurityPass(),
        ])

    def run(self, program: Program,
            analysis: Optional[ProgramAnalysis] = None) -> Plan:
        draft = PlanDraft.from_program(program, analysis)
        for p in self.passes:
            p.run(draft)
        return self.finalize(draft)

    @staticmethod
    def finalize(draft: PlanDraft) -> Plan:
        meta = dict(draft.meta)
        meta.setdefault("var_nbytes", draft.var_nbytes())
        return Plan(program=draft.program, ops=list(draft.ops),
                    groups=dict(draft.groups),
                    io_table=draft.analysis.io_table, meta=meta)
