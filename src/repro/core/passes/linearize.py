"""Linearize pass: program blocks + loop markers → the plan skeleton.

Also hosts the skeleton-position helpers every placement policy uses
(ASAP/ALAP insertion points, Figs. 2-3 of the paper) and the merge of
computed insertions back into the op stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from ..analysis import common_prefix
from ..ir import PlanOp, Program
from .base import Pass, PlanDraft

__all__ = ["LinearizePass", "Insertion", "linearize", "pos_of_block",
           "depth_at", "after_hoisted", "before_hoisted", "merge"]


def linearize(program: Program) -> List[PlanOp]:
    ops: List[PlanOp] = []
    open_path: Tuple[int, ...] = ()
    for blk in program.blocks:
        path = blk.loop_path
        keep = common_prefix(open_path, path)
        for lid in reversed(open_path[len(keep):]):
            ops.append(PlanOp(kind="loop_end", loop_id=lid))
        for lid in path[len(keep):]:
            ops.append(PlanOp(kind="loop_begin", loop_id=lid))
        open_path = path
        ops.append(PlanOp(kind="block", block_idx=blk.idx))
    for lid in reversed(open_path):
        ops.append(PlanOp(kind="loop_end", loop_id=lid))
    return ops


class LinearizePass(Pass):
    """Build the skeleton.  Idempotent: only runs on an empty draft."""

    name = "linearize"

    def run(self, draft: PlanDraft) -> None:
        if not draft.ops:
            draft.ops = linearize(draft.program)


# --------------------------------------------------------------------------
# Skeleton-position helpers (shared by placement policies).
# --------------------------------------------------------------------------

def pos_of_block(ops: List[PlanOp], idx: int) -> int:
    for i, op in enumerate(ops):
        if op.kind == "block" and op.block_idx == idx:
            return i
    raise KeyError(idx)


def depth_at(ops: List[PlanOp], pos: int) -> Tuple[int, ...]:
    path: List[int] = []
    for op in ops[:pos]:
        if op.kind == "loop_begin":
            path.append(op.loop_id)
        elif op.kind == "loop_end":
            path.pop()
    return tuple(path)


def after_hoisted(ops: List[PlanOp], blk_pos: int,
                  target_path: Tuple[int, ...]) -> int:
    """Insertion index just after ``blk_pos`` once all loops deeper than
    ``target_path`` have closed (ASAP placement, Fig. 2)."""
    path = list(depth_at(ops, blk_pos))
    i = blk_pos + 1
    while tuple(path) != tuple(target_path) and i < len(ops):
        op = ops[i]
        if op.kind == "loop_begin":
            path.append(op.loop_id)
        elif op.kind == "loop_end":
            path.pop()
        i += 1
    return i


def before_hoisted(ops: List[PlanOp], blk_pos: int,
                   target_path: Tuple[int, ...]) -> int:
    """Insertion index just before ``blk_pos``, lifted before any
    loop_begin opening loops deeper than ``target_path`` (ALAP
    placement, Fig. 3)."""
    path = list(depth_at(ops, blk_pos))
    i = blk_pos
    while tuple(path) != tuple(target_path) and i > 0:
        op = ops[i - 1]
        if op.kind == "loop_begin":
            path.pop()
        elif op.kind == "loop_end":
            path.append(op.loop_id)
        i -= 1
    return i


@dataclasses.dataclass
class Insertion:
    pos: int           # index into skeleton ops; inserted before ops[pos]
    order: int         # tie-break: stable order of creation
    op: PlanOp


def merge(ops: List[PlanOp], ins: List[Insertion]) -> List[PlanOp]:
    out: List[PlanOp] = []
    by_pos: Dict[int, List[Insertion]] = {}
    for i in ins:
        by_pos.setdefault(i.pos, []).append(i)
    for pos in by_pos:
        by_pos[pos].sort(key=lambda x: x.order)
    for idx in range(len(ops) + 1):
        for i in by_pos.get(idx, ()):
            out.append(i.op)
        if idx < len(ops):
            out.append(ops[idx])
    return out
