"""Purity marking pass — the proof the compiler relies on for whole-loop
lowering (``lax.fori_loop`` over the body, possibly nested).

A loop id is pure iff its body in THIS plan holds only offload blocks
and metadata/sync directives — no host blocks and no
``AdvancedLoad``/``DelegateStore``/``Release``.  The compiled path may
roll such a loop (or a nest of such loops) whole into one fused launch,
because no per-iteration op needs the host.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from ..ir import (AdvancedLoad, BlockKind, DelegateStore, PlanOp, Program,
                  Release)
from .base import Pass, PlanDraft

__all__ = ["PurityPass", "pure_device_loops"]


def pure_device_loops(program: Program,
                      ops: List[PlanOp]) -> Tuple[int, ...]:
    pure: Dict[int, bool] = {}
    stack: List[int] = []
    for op in ops:
        if op.kind == "loop_begin":
            stack.append(op.loop_id)
            pure.setdefault(op.loop_id, True)
        elif op.kind == "loop_end":
            stack.pop()
        elif stack:
            ok = True
            if op.kind == "block":
                ok = program.blocks[op.block_idx].kind is BlockKind.OFFLOAD
            elif op.kind == "directive":
                ok = not isinstance(
                    op.directive, (AdvancedLoad, DelegateStore, Release))
            if not ok:
                for lid in stack:
                    pure[lid] = False
    return tuple(sorted(lid for lid, v in pure.items() if v))


class PurityPass(Pass):
    name = "purity"

    def run(self, draft: PlanDraft) -> None:
        draft.meta["pure_device_loops"] = pure_device_loops(
            draft.program, draft.ops)
