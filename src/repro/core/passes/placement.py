"""Placement policies — where transfers/syncs go (the paper's §2 axis).

Each policy is a pass that computes directive insertions against the
skeleton and merges them into ``draft.ops``.  Policies are registered by
name so the tuner can enumerate them and downstream code can add its
own:

    ``optimized``  advancedload ASAP / delegatestore ALAP / async+sync /
                   residency reuse (Figs. 4b/5b — the paper's system)
    ``naive``      every transfer at the callsite, synchronous
                   (Figs. 4a/5a — the paper's baseline)
    ``grouped``    optimized placement with every codelet folded into
                   ONE directive group (single mapbyname space, one
                   release, one transfer stream) — the paper's grouping
                   axis pushed to its endpoint
    ``pipeline``   optimized placement with every codelet in its OWN
                   group — the GPipe stage schedule from
                   ``distributed.pipeline`` expressed as a placement:
                   per-stage transfer streams and releases so stage
                   i+1's uploads overlap stage i's compute

``register_placement`` admits new policies; ``GroupFinalizePass`` emits
the group declarations (head) and releases (tail) from whatever grouping
the policy left in the draft.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Set, Type

from ..analysis import common_prefix
from ..ir import (AdvancedLoad, BlockKind, DelegateStore, GroupDecl, PlanOp,
                  Release, Synchronize, VarIO)
from .base import Pass, PlanDraft
from .linearize import (Insertion, after_hoisted, before_hoisted, merge,
                        pos_of_block)

__all__ = ["PlacementPass", "OptimizedPlacement", "NaivePlacement",
           "GroupedPlacement", "PipelinePlacement", "GroupFinalizePass",
           "register_placement", "get_placement", "placement_names"]


class PlacementPass(Pass):
    """Base: compute insertions, merge them into the skeleton."""

    name = "placement"
    policy = "abstract"
    elide = True      # let SimulateFixPass drop always-redundant transfers

    def run(self, draft: PlanDraft) -> None:
        ins = self.place(draft)
        draft.ops = merge(draft.ops, ins)
        draft.meta["policy"] = self.policy

    def place(self, draft: PlanDraft) -> List[Insertion]:
        raise NotImplementedError


class OptimizedPlacement(PlacementPass):
    """The paper's optimized placement (Figs. 2, 3, 4b, 5b)."""

    name = "place:optimized"
    policy = "optimized"
    elide = True

    def place(self, draft: PlanDraft) -> List[Insertion]:
        an = draft.analysis
        program = draft.program
        ops = draft.ops
        ins: List[Insertion] = []
        order = [0]

        def add(pos: int, directive) -> None:
            ins.append(Insertion(pos, order[0],
                                 PlanOp("directive", directive=directive)))
            order[0] += 1

        seen_loads: Set = set()       # (var, pos) dedupe
        seen_stores: Set = set()

        def straight_load(var, g, blk, lw):
            """ASAP load covering the straight-line (iteration-1) path."""
            if lw is None:
                pos, hoisted = 0, ()
            else:
                target = common_prefix(lw.loop_path, blk.loop_path)
                writer_pos = pos_of_block(ops, lw.block_idx)
                pos = after_hoisted(ops, writer_pos, target)
                hoisted = lw.loop_path[len(target):]
            if (var, pos) not in seen_loads:
                seen_loads.add((var, pos))
                add(pos, AdvancedLoad(var=var, group=g, asynchronous=True,
                                      hoisted_from=hoisted))

        for blk in program.offload_blocks():
            g = draft.group_of[blk.idx]
            blk_pos = pos_of_block(ops, blk.idx)

            # ---- inputs: AdvancedLoad, hoisted ASAP (Fig. 2 / 4b) --------
            # The dynamic last write at the callsite is lw (straight-line,
            # iteration 1) and — when the callsite sits in a loop whose
            # body also writes the var AFTER it — lwc (loop-carried,
            # iterations ≥ 2).
            for var, io in sorted(an.io_table[blk.idx].items()):
                if io is VarIO.OUT:
                    continue  # never read by the codelet: no upload (E)
                lw = an.last_write_before(var, blk.idx)
                lwc = an.last_carried_write(var, blk)
                straight_resident = (lw is not None
                                     and lw.kind is BlockKind.OFFLOAD)
                if lwc is None:
                    if straight_resident:
                        continue          # noupdate (tagged later)
                    straight_load(var, g, blk, lw)
                elif lwc.kind is BlockKind.OFFLOAD:
                    # iterations ≥ 2 are device-resident; cover iteration 1
                    if not straight_resident:
                        straight_load(var, g, blk, lw)
                else:
                    # carried HOST write: iterations ≥ 2 need a fresh load
                    if straight_resident:
                        # iter 1 resident → ASAP after the carried writer
                        # (end of body i covers body i+1's read)
                        target = common_prefix(lwc.loop_path, blk.loop_path)
                        wpos = pos_of_block(ops, lwc.block_idx)
                        pos = after_hoisted(ops, wpos, target)
                        hoisted = lwc.loop_path[len(target):]
                    else:
                        # host-fresh on every path → one load just before
                        # the callsite (count-optimal; matches naive here)
                        pos, hoisted = blk_pos, ()
                    if (var, pos) not in seen_loads:
                        seen_loads.add((var, pos))
                        add(pos, AdvancedLoad(var=var, group=g,
                                              asynchronous=True,
                                              hoisted_from=hoisted))

            # ---- outputs: DelegateStore, sunk ALAP (Fig. 3 / 5b) ---------
            for var, io in sorted(an.io_table[blk.idx].items()):
                if io is VarIO.IN:
                    continue
                carried_r = an.carried_host_read(var, blk)
                if carried_r is not None:
                    # a host block EARLIER in the shared loop reads next
                    # iteration's value → store right after the callsite
                    pos = blk_pos + 1
                    if (var, pos) not in seen_stores:
                        seen_stores.add((var, pos))
                        add(pos, Synchronize(block_idx=blk.idx, group=g))
                        add(pos, DelegateStore(var=var, group=g))
                reader = an.first_host_read_after(var, blk.idx)
                if reader is None:
                    if var in getattr(program, "outputs", ()):  # end read
                        killed = any(
                            ev.is_write and ev.block_idx > blk.idx
                            for ev in an.events.get(var, ()))
                        if killed:
                            continue
                        pos = len(ops)
                        add(pos, Synchronize(block_idx=blk.idx, group=g))
                        add(pos, DelegateStore(var=var, group=g))
                    continue  # dead on host: no download (paper: A)
                target = common_prefix(blk.loop_path, reader.loop_path)
                reader_pos = pos_of_block(ops, reader.block_idx)
                pos = before_hoisted(ops, reader_pos, target)
                if (var, pos) in seen_stores:
                    continue
                seen_stores.add((var, pos))
                hoisted = reader.loop_path[len(target):]
                # synchronize the async callsite before its first host use
                add(pos, Synchronize(block_idx=blk.idx, group=g))
                add(pos, DelegateStore(var=var, group=g,
                                       hoisted_from=hoisted))

        return ins


class NaivePlacement(PlacementPass):
    """Paper Figs. 4a/5a: all transfers at the callsite, synchronous."""

    name = "place:naive"
    policy = "naive"
    elide = False     # the baseline keeps its redundant transfers

    def place(self, draft: PlanDraft) -> List[Insertion]:
        an = draft.analysis
        ops = draft.ops
        ins: List[Insertion] = []
        order = [0]

        def add(pos, directive):
            ins.append(Insertion(pos, order[0],
                                 PlanOp("directive", directive=directive)))
            order[0] += 1

        for blk in draft.program.offload_blocks():
            g = draft.group_of[blk.idx]
            pos = pos_of_block(ops, blk.idx)
            for var, io in sorted(an.io_table[blk.idx].items()):
                if io is not VarIO.OUT:
                    add(pos, AdvancedLoad(var=var, group=g,
                                          asynchronous=False))
            outs = [var for var, io in sorted(an.io_table[blk.idx].items())
                    if io is not VarIO.IN]
            if outs:
                # one wait point per callsite (Fig. 5a), then every
                # download — not a sync per output
                add(pos + 1, Synchronize(block_idx=blk.idx, group=g))
                for var in outs:
                    add(pos + 1, DelegateStore(var=var, group=g))
        return ins


class GroupedPlacement(OptimizedPlacement):
    """Optimized placement with all codelets folded into one group."""

    name = "place:grouped"
    policy = "grouped"
    elide = True

    def place(self, draft: PlanDraft) -> List[Insertion]:
        blocks = tuple(b.idx for b in draft.program.offload_blocks())
        draft.groups = {0: blocks} if blocks else {}
        draft.group_of = {bi: 0 for bi in blocks}
        return super().place(draft)


class PipelinePlacement(OptimizedPlacement):
    """Optimized placement with every codelet in its own group — the
    ``distributed.pipeline`` GPipe stage schedule as a placement policy.

    One group per offload block means one mapbyname space, one release
    and (under ``n_transfer_streams > 1``) one transfer stream per
    *stage*, so stage i+1's advancedloads overlap stage i's codelet the
    way GPipe overlaps micro-batch (i+1)'s weights with micro-batch i's
    forward.  The inverse of ``grouped``: that folds all stages into one
    group, this splits them maximally."""

    name = "place:pipeline"
    policy = "pipeline"
    elide = True

    def place(self, draft: PlanDraft) -> List[Insertion]:
        blocks = tuple(b.idx for b in draft.program.offload_blocks())
        draft.groups = {i: (bi,) for i, bi in enumerate(blocks)}
        draft.group_of = {bi: i for i, bi in enumerate(blocks)}
        return super().place(draft)


class GroupFinalizePass(Pass):
    """Group declarations up front, releases at the end (paper Table 2)."""

    name = "groups"

    def run(self, draft: PlanDraft) -> None:
        program = draft.program
        if any(op.kind == "directive" and isinstance(op.directive, GroupDecl)
               for op in draft.ops):
            return        # head/tail already emitted (idempotent)
        head: List[PlanOp] = []
        for g, blks in sorted(draft.groups.items()):
            shared: Set[str] = set()
            seen: Set[str] = set()
            for bi in blks:
                for v in set(program.blocks[bi].effective_reads()) | \
                        set(program.blocks[bi].writes):
                    if v in seen:
                        shared.add(v)
                    seen.add(v)
            head.append(PlanOp("directive", directive=GroupDecl(
                group=g, mapbyname=tuple(sorted(shared)), target="TPU")))
        tail = [PlanOp("directive", directive=Release(group=g))
                for g in sorted(draft.groups)]
        draft.ops = head + draft.ops + tail


# --------------------------------------------------------------------------
# Policy registry — the tuner's placement axis.
# --------------------------------------------------------------------------

_PLACEMENTS: Dict[str, Type[PlacementPass]] = {
    "optimized": OptimizedPlacement,
    "naive": NaivePlacement,
    "grouped": GroupedPlacement,
}




def register_placement(name: str,
                       cls: Callable[[], PlacementPass]) -> None:
    """Add a placement policy; it becomes plannable via
    ``plan(p, policy=name)`` and enumerable by the tuner."""
    _PLACEMENTS[name] = cls


# the GPipe-derived stage schedule registers through the same admission
# path any external policy would
register_placement("pipeline", PipelinePlacement)


def get_placement(name: str) -> Type[PlacementPass]:
    try:
        return _PLACEMENTS[name]
    except KeyError:
        raise ValueError(f"unknown placement policy {name!r}; have "
                         f"{sorted(_PLACEMENTS)}") from None


def placement_names() -> List[str]:
    return sorted(_PLACEMENTS)
