"""Simulate-and-fix + noupdate tagging passes.

``SimulateFixPass`` is the validity authority: an abstract interpretation
of the plan (loop bodies twice — the standard 2-iteration trick) tracks
per-variable host/device validity, drops loads/stores that are redundant
on *every* execution (optimized policy only) and inserts emergency
transfers if a placement gap is found.  A plan whose gap cannot be fixed
(no valid copy anywhere) raises — the tuner uses this to reject invalid
candidate plans instead of ranking them.

``NoupdatePass`` annotates each callsite with the inputs that arrive
device-resident — the paper's ``args[x].noupdate=true``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

from ..ir import (AdvancedLoad, BlockKind, Callsite, DelegateStore, PlanOp,
                  Program, Synchronize, VarIO)
from .base import Pass, PlanDraft

__all__ = ["SimulateFixPass", "NoupdatePass", "PlanGap", "simulate"]


class PlanGap(Exception):
    """An unfixable placement hole: a read with no valid copy anywhere."""


@dataclasses.dataclass
class _VState:
    valid_host: bool
    valid_device: bool


def simulate(program: Program, ops: List[PlanOp]):
    """Walk the plan; loop bodies are interpreted twice so cross-iteration
    residency is exact for programs whose bodies don't change behaviour
    after iteration 2 (ours don't: block read/write sets are static).

    Returns (always_redundant positions, gaps) where gaps is a list of
    (pos, emergency PlanOps) needed for correctness — an emergency
    download arrives with its own preceding ``Synchronize`` so the fixed
    plan passes the static verifier's async-race check.  Raises
    ``PlanGap`` when no emergency transfer can fix a hole.
    """
    state: Dict[str, _VState] = {
        v: _VState(True, False) for v in program.inputs
    }
    load_hits: Dict[int, List[bool]] = {}   # op position -> redundancy
    store_hits: Dict[int, List[bool]] = {}
    gaps: Dict[Tuple[int, str, str], Tuple[int, Tuple[PlanOp, ...]]] = {}

    # pre-index loop spans
    spans: Dict[int, Tuple[int, int]] = {}
    stack: List[Tuple[int, int]] = []
    for i, op in enumerate(ops):
        if op.kind == "loop_begin":
            stack.append((op.loop_id, i))
        elif op.kind == "loop_end":
            lid, start = stack.pop()
            spans[lid] = (start, i)

    def exec_range(lo: int, hi: int):
        i = lo
        while i < hi:
            op = ops[i]
            if op.kind == "loop_begin":
                start, end = spans[op.loop_id]
                for _ in range(2):           # 2-iteration abstraction
                    exec_range(start + 1, end)
                i = end + 1
                continue
            if op.kind == "directive":
                d = op.directive
                if isinstance(d, AdvancedLoad):
                    st = state.setdefault(d.var, _VState(False, False))
                    if not st.valid_host:
                        # a host copy is required; upstream store missing
                        raise PlanGap(
                            f"load of {d.var!r} with no valid host copy")
                    load_hits.setdefault(i, []).append(st.valid_device)
                    st.valid_device = True
                elif isinstance(d, DelegateStore):
                    st = state.setdefault(d.var, _VState(False, False))
                    if not st.valid_device:
                        raise PlanGap(
                            f"store of {d.var!r} with no valid device copy")
                    store_hits.setdefault(i, []).append(st.valid_host)
                    st.valid_host = True
            elif op.kind == "block":
                blk = program.blocks[op.block_idx]
                on_device = blk.kind is BlockKind.OFFLOAD
                for v in blk.effective_reads():
                    st = state.setdefault(v, _VState(False, False))
                    ok = st.valid_device if on_device else st.valid_host
                    if not ok:
                        src_ok = st.valid_host if on_device else \
                            st.valid_device
                        if not src_ok:
                            raise PlanGap(
                                f"{blk.name!r} reads {v!r} but no valid "
                                "copy exists anywhere")
                        if on_device:
                            fix = (PlanOp("directive",
                                          directive=AdvancedLoad(
                                              v, group=0,
                                              asynchronous=False)),)
                        else:
                            # the emergency download must be preceded by
                            # a wait point: the device value may come
                            # from an asynchronous callsite, and an
                            # unsynchronized d2h of it is the async race
                            # the plan verifier rejects
                            fix = (PlanOp("directive",
                                          directive=Synchronize(
                                              block_idx=-1, group=0)),
                                   PlanOp("directive",
                                          directive=DelegateStore(
                                              v, group=0)))
                        key = (i, v, type(fix[-1].directive).__name__)
                        gaps.setdefault(key, (i, fix))
                        if on_device:
                            st.valid_device = True
                        else:
                            st.valid_host = True
                for v in blk.writes:
                    st = state.setdefault(v, _VState(False, False))
                    if on_device:
                        st.valid_device, st.valid_host = True, False
                    else:
                        st.valid_host, st.valid_device = True, False
            i += 1

    exec_range(0, len(ops))
    always_redundant = {
        pos for pos, flags in load_hits.items() if flags and all(flags)
    }
    always_redundant |= {
        pos for pos, flags in store_hits.items() if flags and all(flags)
    }
    return always_redundant, list(gaps.values())


class SimulateFixPass(Pass):
    """Validate, elide redundant transfers, insert emergency fixes."""

    name = "simulate_fix"

    def __init__(self, *, elide: bool = True, max_rounds: int = 8):
        self.elide = elide
        self.max_rounds = max_rounds

    def run(self, draft: PlanDraft) -> None:
        ops = draft.ops
        for _round in range(self.max_rounds):
            try:
                redundant, gaps = simulate(draft.program, ops)
            except PlanGap as e:
                raise RuntimeError(
                    f"planner produced an invalid plan: {e}")
            if gaps:
                # insert emergency transfers (kept rare by construction)
                for pos, fix_ops in sorted(gaps, key=lambda t: -t[0]):
                    ops = ops[:pos] + list(fix_ops) + ops[pos:]
                continue
            if self.elide and redundant:
                ops = [op for i, op in enumerate(ops)
                       if i not in redundant]
                continue
            draft.ops = ops
            return
        raise RuntimeError("planner failed to converge")


class NoupdatePass(Pass):
    """Annotate callsites with device-resident inputs (no AdvancedLoad
    between the last producer and the callsite)."""

    name = "noupdate"

    def run(self, draft: PlanDraft) -> None:
        program, an = draft.program, draft.analysis
        if any(op.kind == "directive" and isinstance(op.directive, Callsite)
               for op in draft.ops):
            return        # already tagged (idempotent)
        loaded_since_host_write: Set[str] = set()
        out: List[PlanOp] = []
        for op in draft.ops:
            if op.kind == "block":
                blk = program.blocks[op.block_idx]
                if blk.kind is BlockKind.OFFLOAD:
                    io = an.io_table[blk.idx]
                    noup = tuple(
                        v for v, d in sorted(io.items())
                        if d is not VarIO.OUT and v not in
                        loaded_since_host_write
                    )
                    out.append(PlanOp("directive", directive=Callsite(
                        block_idx=blk.idx, group=draft.group_of[blk.idx],
                        io=tuple(sorted((v, d.value)
                                        for v, d in io.items())),
                        noupdate=noup, asynchronous=True)))
                    out.append(op)
                    for v in blk.writes:
                        loaded_since_host_write.discard(v)
                    continue
                else:
                    for v in blk.writes:
                        loaded_since_host_write.discard(v)
            if op.kind == "directive" and isinstance(op.directive,
                                                     AdvancedLoad):
                loaded_since_host_write.add(op.directive.var)
            out.append(op)
        draft.ops = out
