"""Stream assignment pass — logical transfer queues per group.

Stream 0 is the compute stream; transfer/sync directives get streams
1..n so a stream-aware backend double-buffers uploads of independent
groups and ``Synchronize`` waits only its own queue.

Determinism contract (ISSUE 4 satellite): stream ids are derived from
the order in which groups FIRST APPEAR among the plan's transfer
directives, not from the group id itself.  Group ids come from
union-find root numbering and may be renumbered between otherwise
identical plans (e.g. by a policy that rewrites the grouping); deriving
streams from appearance order keeps two plans of the same program
op-for-op identical, so the executor's compiled-plan fingerprint
(``hash(tuple(plan.ops))``) matches and cached ``launch_loop``/segment
jits stay valid.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from ..ir import AdvancedLoad, DelegateStore, PlanOp, Synchronize
from .base import Pass, PlanDraft

__all__ = ["StreamAssignPass", "assign_streams"]

_TRANSFER = (AdvancedLoad, DelegateStore, Synchronize)


def assign_streams(ops: List[PlanOp], n_streams: int = 2) -> List[PlanOp]:
    """Rewrite transfer/sync directives with appearance-ordered streams."""
    n = max(1, int(n_streams))
    first_seen: Dict[int, int] = {}
    for op in ops:
        if op.kind == "directive" and isinstance(op.directive, _TRANSFER):
            g = op.directive.group
            if g not in first_seen:
                first_seen[g] = len(first_seen)

    def stream_of(group: int) -> int:
        return 1 + first_seen.get(group, group) % n

    out: List[PlanOp] = []
    for op in ops:
        d = op.directive
        if op.kind == "directive" and isinstance(d, _TRANSFER):
            d = dataclasses.replace(d, stream=stream_of(d.group))
            op = PlanOp("directive", directive=d)
        out.append(op)
    return out


class StreamAssignPass(Pass):
    """Parameterized on the transfer-stream count (the tuner's axis)."""

    name = "streams"

    def __init__(self, n_streams: int = 2):
        self.n_streams = n_streams

    def run(self, draft: PlanDraft) -> None:
        draft.ops = assign_streams(draft.ops, self.n_streams)
        draft.meta["n_transfer_streams"] = max(1, int(self.n_streams))
