"""Planner pass pipeline (ISSUE 4): composable, reorderable passes over a
shared ``PlanDraft``.  See ``base.Pipeline.default`` for the canonical
order and ``placement.register_placement`` for adding policies."""
from .base import Pass, Pipeline, PlanDraft
from .linearize import LinearizePass, linearize
from .placement import (GroupedPlacement, GroupFinalizePass, NaivePlacement,
                        OptimizedPlacement, PlacementPass, get_placement,
                        placement_names, register_placement)
from .purity import PurityPass, pure_device_loops
from .simulate import NoupdatePass, PlanGap, SimulateFixPass, simulate
from .streams import StreamAssignPass, assign_streams

__all__ = [
    "Pass", "Pipeline", "PlanDraft",
    "LinearizePass", "linearize",
    "PlacementPass", "OptimizedPlacement", "NaivePlacement",
    "GroupedPlacement", "GroupFinalizePass",
    "register_placement", "get_placement", "placement_names",
    "SimulateFixPass", "NoupdatePass", "PlanGap", "simulate",
    "StreamAssignPass", "assign_streams",
    "PurityPass", "pure_device_loops",
]
