"""Plan → compiled schedule lowering.

The interpreter in ``executor.py`` re-dispatches every directive and block
through Python each time it is reached — a loop body with three codelets
costs three jit-call boundaries plus directive dispatch *per iteration*.
This module lowers a ``Plan`` once into a **compiled schedule**:

* Maximal runs of offload blocks and their transfer directives (no host
  blocks, no loop boundaries, no ``Release``) become a ``_Segment``.
* Each segment's blocks are traced together into ONE fused function and
  compiled by the backend (``jax.jit`` for device backends) a single
  time; loop iterations re-enter the compiled code.  Uploads stay outside
  the trace (they are real h2d transfers, counted per execution, enqueued
  async on the directive's stream); the values a ``DelegateStore``
  captures mid-segment are threaded out as extra fused outputs so the
  download sees exactly the value at the store's program point.
* Host blocks, loops and ``Release`` fall back to the interpreter's
  primitives.

Contract (tested): for any plan, ``execute(p, mode="compiled")`` returns
bitwise-identical outputs to ``execute(p, mode="interpreted")`` on the
same backend, with identical *logical* ``ExecStats`` transfer counts —
only wall-time fields (and ``fused_launches``) differ.

A segment is split before an ``AdvancedLoad`` whose variable an earlier
op in the same segment dirtied — stored (the upload must observe the
host value the download produced) or block-wrote (the interpreter
rejects the now-stale host copy, and so must we) — since the driver
issues every upload before the fused launch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .backend import Backend
from .executor import (ExecStats, PlanExecutionError, _Slot, _nest,
                       _run_block, do_load, do_release, do_store, do_sync,
                       dummy_arg)
from .ir import (AdvancedLoad, BlockKind, Callsite, DelegateStore, GroupDecl,
                 Plan, PlanOp, Program, Release, Synchronize)

__all__ = ["compile_plan", "CompiledPlan"]


# --------------------------------------------------------------------------
# Segment representation.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Segment:
    """A fused run of directives + offload blocks.

    ``items`` is the ordered lowering of the run:
        ('load',  AdvancedLoad, load_index)
        ('store', DelegateStore, store_index)
        ('sync',  Synchronize)
        ('block', block_idx)
    ``arg_spec`` describes the fused function's positional arguments:
        ('entry', var)   device value resident at segment entry
        ('load',  i)     the handle uploaded by load #i this execution
        ('dummy', var)   zeros for a pruned (dead) declared read
    """
    items: List[Tuple]
    arg_spec: List[Tuple[str, Any]]
    blocks: List[int]
    n_stores: int
    final_writes: Tuple[str, ...]
    fused: Optional[Callable[..., Tuple[Any, ...]]] = None


def _build_segment(run: List[PlanOp], program: Program) -> _Segment:
    items: List[Tuple] = []
    arg_spec: List[Tuple[str, Any]] = []
    arg_index: Dict[Tuple[str, Any], int] = {}
    defined: set = set()          # vars bound inside the trace
    blocks: List[int] = []
    writes_order: List[str] = []
    n_loads = n_stores = 0

    def argpos(key: Tuple[str, Any]) -> int:
        if key not in arg_index:
            arg_index[key] = len(arg_spec)
            arg_spec.append(key)
        return arg_index[key]

    def need(var: str) -> None:
        if var not in defined:
            argpos(("entry", var))
            defined.add(var)

    for op in run:
        if op.kind == "directive":
            d = op.directive
            if isinstance(d, AdvancedLoad):
                argpos(("load", n_loads))
                items.append(("load", d, n_loads))
                defined.add(d.var)
                n_loads += 1
            elif isinstance(d, DelegateStore):
                need(d.var)
                items.append(("store", d, n_stores))
                n_stores += 1
            elif isinstance(d, Synchronize):
                items.append(("sync", d))
            # GroupDecl / Callsite are metadata: dropped from the lowering
        else:
            blk = program.blocks[op.block_idx]
            actual = set(blk.effective_reads())
            for v in blk.reads:
                if v in actual:
                    need(v)
                else:
                    argpos(("dummy", v))
            items.append(("block", blk.idx))
            blocks.append(blk.idx)
            for w in blk.writes:
                defined.add(w)
                if w not in writes_order:
                    writes_order.append(w)

    return _Segment(items=items, arg_spec=arg_spec, blocks=blocks,
                    n_stores=n_stores, final_writes=tuple(writes_order))


def _make_fused(seg: _Segment, program: Program, xp):
    """The traced body: replays the segment symbolically; returns the
    store-captured values followed by the final device value of every
    block-written variable."""
    entry_pos = {k[1]: i for i, k in enumerate(seg.arg_spec)
                 if k[0] == "entry"}
    load_pos = {k[1]: i for i, k in enumerate(seg.arg_spec)
                if k[0] == "load"}
    dummy_pos = {k[1]: i for i, k in enumerate(seg.arg_spec)
                 if k[0] == "dummy"}

    def fused(*args):
        env = {v: args[i] for v, i in entry_pos.items()}
        stores: List[Any] = [None] * seg.n_stores
        for it in seg.items:
            if it[0] == "load":
                env[it[1].var] = args[load_pos[it[2]]]
            elif it[0] == "block":
                blk = program.blocks[it[1]]
                actual = set(blk.effective_reads())
                kwargs = {v: (env[v] if v in actual
                              else args[dummy_pos[v]])
                          for v in blk.reads}
                out = blk.fn(xp, **kwargs)
                for w in blk.writes:
                    env[w] = out[w]
            elif it[0] == "store":
                stores[it[2]] = env[it[1].var]
        return tuple(stores) + tuple(env[v] for v in seg.final_writes)

    return fused


def _donatable(seg: _Segment) -> Tuple[int, ...]:
    """Args safe to donate: device inputs whose variable the segment
    rewrites — after the fused call the driver only keeps the new value."""
    rewritten = set(seg.final_writes)
    out = []
    for i, (tag, v) in enumerate(seg.arg_spec):
        if tag == "entry" and v in rewritten:
            out.append(i)
    return tuple(out)


# --------------------------------------------------------------------------
# Lowering: plan tree -> schedule of host blocks / segments / loops.
# --------------------------------------------------------------------------

def _lower(tree, program: Program, be: Backend) -> List[Tuple]:
    schedule: List[Tuple] = []
    run: List[PlanOp] = []
    # vars whose host copy an in-segment op has changed (DelegateStore) or
    # invalidated (a block write): a later AdvancedLoad of such a var must
    # start a new segment, because the driver issues every upload before
    # the fused launch and would otherwise read the pre-segment host value
    # (or silently accept a host copy the interpreter rejects as stale)
    dirty_vars: set = set()

    def flush() -> None:
        nonlocal run, dirty_vars
        if run:
            seg = _build_segment(run, program)
            if seg.blocks:
                fused = _make_fused(seg, program, be.xp)
                seg.fused = be.compile_fused(fused, _donatable(seg))
            schedule.append(("seg", seg))
        run, dirty_vars = [], set()

    for item in tree:
        if item[0] == "loop":
            flush()
            _, loop_id, body = item
            schedule.append(("loop", loop_id, _lower(body, program, be)))
            continue
        op: PlanOp = item[1]
        if op.kind == "block":
            blk = program.blocks[op.block_idx]
            if blk.kind is BlockKind.HOST:
                flush()
                schedule.append(("host", blk.idx))
            else:
                run.append(op)
                dirty_vars.update(blk.writes)
            continue
        d = op.directive
        if isinstance(d, Release):
            flush()
            schedule.append(("release",))
        elif isinstance(d, (GroupDecl, Callsite)):
            continue
        elif isinstance(d, AdvancedLoad) and d.var in dirty_vars:
            flush()          # upload must see the in-segment host state
            run.append(op)
        else:
            if isinstance(d, DelegateStore):
                dirty_vars.add(d.var)
            run.append(op)
    flush()
    return schedule


# --------------------------------------------------------------------------
# Compiled plan driver.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledPlan:
    plan: Plan
    backend: Backend
    schedule: List[Tuple]

    def run(self, env: Dict[str, _Slot], stats: ExecStats,
            check: bool) -> None:
        self._run_schedule(self.schedule, env, stats, check)

    def _run_schedule(self, schedule, env, stats, check) -> None:
        program = self.plan.program
        be = self.backend
        for item in schedule:
            kind = item[0]
            if kind == "loop":
                for _ in range(program.loops[item[1]].n_iters):
                    self._run_schedule(item[2], env, stats, check)
            elif kind == "host":
                _run_block(program, item[1], env, stats, check, be)
            elif kind == "release":
                do_release(env, be)
            else:
                self._run_segment(item[1], env, stats, check)

    def _run_segment(self, seg: _Segment, env, stats: ExecStats,
                     check: bool) -> None:
        be = self.backend
        # 1. issue every upload (async, on its directive's stream) --------
        load_handles: Dict[int, Any] = {}
        for it in seg.items:
            if it[0] == "load":
                load_handles[it[2]] = do_load(it[1], env, stats, be)

        if not seg.blocks:
            # pure transfer/sync segment: no compute to fuse
            for it in seg.items:
                if it[0] == "sync":
                    do_sync(it[1], stats, be)
                elif it[0] == "store":
                    do_store(it[1], env, stats, be)
            return

        # 2. gather fused args --------------------------------------------
        args: List[Any] = []
        for tag, v in seg.arg_spec:
            if tag == "load":
                args.append(load_handles[v])
                continue
            slot = env.setdefault(v, _Slot())
            if tag == "dummy":
                args.append(dummy_arg(slot, be))
                continue
            if not slot.valid_device:
                if check:
                    raise PlanExecutionError(
                        f"compiled segment reads {v!r}: not on device "
                        f"(missing advancedload)")
                slot.device = be.upload(slot.host)
                slot.valid_device = True
            args.append(slot.device)

        # 3. one fused launch for the whole segment -----------------------
        t = time.perf_counter()
        outs = seg.fused(*args)
        stats.kernel_time += time.perf_counter() - t
        stats.kernel_calls += len(seg.blocks)   # logical count parity
        stats.fused_launches += 1
        for o in outs:
            be.track(o, stream=0)
        store_vals = outs[:seg.n_stores]
        final_map = dict(zip(seg.final_writes, outs[seg.n_stores:]))

        # 4. replay directives/flags in program order ---------------------
        for it in seg.items:
            if it[0] == "sync":
                do_sync(it[1], stats, be)
            elif it[0] == "store":
                do_store(it[1], env, stats, be, handle=store_vals[it[2]])
            elif it[0] == "block":
                blk = self.plan.program.blocks[it[1]]
                for w in blk.writes:
                    slot = env.setdefault(w, _Slot())
                    slot.device = final_map[w]
                    slot.valid_device, slot.valid_host = True, False


def compile_plan(p: Plan, backend: Backend) -> CompiledPlan:
    """Lower ``p`` for ``backend``; segments are traced/compiled lazily on
    first call by the backend's compiler (``jax.jit`` caches thereafter)."""
    tree = _nest(p.ops, p.program)
    schedule = _lower(tree, p.program, backend)
    return CompiledPlan(plan=p, backend=backend, schedule=schedule)
