"""Plan → compiled schedule lowering.

The interpreter in ``executor.py`` re-dispatches every directive and block
through Python each time it is reached — a loop body with three codelets
costs three jit-call boundaries plus directive dispatch *per iteration*.
This module lowers a ``Plan`` once into a **compiled schedule**:

* Maximal runs of offload blocks and their transfer directives (no host
  blocks, no loop boundaries, no ``Release``) become a ``_Segment``.
* Each segment's blocks are traced together into ONE fused function and
  compiled by the backend (``jax.jit`` for device backends) a single
  time; loop iterations re-enter the compiled code.  Uploads stay outside
  the trace (they are real h2d transfers, counted per execution, enqueued
  async on the directive's stream); the values a ``DelegateStore``
  captures mid-segment are threaded out as extra fused outputs so the
  download sees exactly the value at the store's program point.
* A loop whose body lowers to a SINGLE pure-device segment (offload
  blocks and syncs only — no host blocks, no ``AdvancedLoad``/
  ``DelegateStore``/``Release`` inside the body) and that the planner
  has marked loop-invariant (``plan.meta["pure_device_loops"]``) is
  rolled whole into ONE backend dispatch (``Backend.launch_loop``:
  ``jax.jit`` + ``lax.fori_loop`` on device backends, a Python loop
  inside one dispatch on numpy), carrying the segment's device values
  as loop state.  Iterations then run back-to-back on the device with
  no per-iteration Python re-entry at all.
* Host blocks, remaining loops and ``Release`` fall back to the
  interpreter's primitives.

Contract (tested): for any plan, ``execute(p, mode="compiled")`` returns
bitwise-identical outputs to ``execute(p, mode="interpreted")`` on the
same backend, with identical *logical* ``ExecStats`` transfer counts —
``kernel_calls``/``syncs`` still count per iteration inside a fused
loop while ``fused_launches`` counts 1; only wall-time fields (and
``fused_launches``) differ.

A segment is split before an ``AdvancedLoad`` whose variable an earlier
op in the same segment dirtied — stored (the upload must observe the
host value the download produced) or block-wrote (the interpreter
rejects the now-stale host copy, and so must we) — since the driver
issues every upload before the fused launch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from .backend import Backend
from .executor import (ExecStats, PlanExecutionError, _nest, _run_block,
                       _Slot, do_load, do_release, do_store, do_sync,
                       dummy_arg, kernel_fn)
from .ir import (AdvancedLoad, BlockKind, Callsite, DelegateStore, GroupDecl,
                 Plan, PlanOp, Program, Release, Synchronize)

__all__ = ["compile_plan", "CompiledPlan", "fusable_loops"]


# --------------------------------------------------------------------------
# Segment representation.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Segment:
    """A fused run of directives + offload blocks.

    ``items`` is the ordered lowering of the run:
        ('load',  AdvancedLoad, load_index)
        ('store', DelegateStore, store_index)
        ('sync',  Synchronize)
        ('block', block_idx)
    ``arg_spec`` describes the fused function's positional arguments:
        ('entry', var)   device value resident at segment entry
        ('load',  i)     the handle uploaded by load #i this execution
        ('dummy', var)   zeros for a pruned (dead) declared read
    """
    items: List[Tuple]
    arg_spec: List[Tuple[str, Any]]
    blocks: List[int]
    n_stores: int
    final_writes: Tuple[str, ...]
    fused: Optional[Callable[..., Tuple[Any, ...]]] = None


def _build_segment(run: List[PlanOp], program: Program) -> _Segment:
    items: List[Tuple] = []
    arg_spec: List[Tuple[str, Any]] = []
    arg_index: Dict[Tuple[str, Any], int] = {}
    defined: set = set()          # vars bound inside the trace
    blocks: List[int] = []
    writes_order: List[str] = []
    n_loads = n_stores = 0

    def argpos(key: Tuple[str, Any]) -> int:
        if key not in arg_index:
            arg_index[key] = len(arg_spec)
            arg_spec.append(key)
        return arg_index[key]

    def need(var: str) -> None:
        if var not in defined:
            argpos(("entry", var))
            defined.add(var)

    for op in run:
        if op.kind == "directive":
            d = op.directive
            if isinstance(d, AdvancedLoad):
                argpos(("load", n_loads))
                items.append(("load", d, n_loads))
                defined.add(d.var)
                n_loads += 1
            elif isinstance(d, DelegateStore):
                need(d.var)
                items.append(("store", d, n_stores))
                n_stores += 1
            elif isinstance(d, Synchronize):
                items.append(("sync", d))
            # GroupDecl / Callsite are metadata: dropped from the lowering
        else:
            blk = program.blocks[op.block_idx]
            actual = set(blk.effective_reads())
            for v in blk.reads:
                if v in actual:
                    need(v)
                else:
                    argpos(("dummy", v))
            items.append(("block", blk.idx))
            blocks.append(blk.idx)
            for w in blk.writes:
                defined.add(w)
                if w not in writes_order:
                    writes_order.append(w)

    return _Segment(items=items, arg_spec=arg_spec, blocks=blocks,
                    n_stores=n_stores, final_writes=tuple(writes_order))


def _replay_block(blk, xp, env: Dict[str, Any], get_dummy,
                  variants=None) -> None:
    """The single shared per-block replay both compiled paths trace:
    actual reads come from ``env``, pruned (dead) declared reads from
    ``get_dummy(var)``, and every write lands back in ``env``.  Keeping
    this in one place is what keeps segment mode and fused-loop mode
    bitwise-interchangeable (and is the one spot kernel tile variants
    bind into compiled traces)."""
    actual = set(blk.effective_reads())
    kwargs = {v: (env[v] if v in actual else get_dummy(v))
              for v in blk.reads}
    out = kernel_fn(blk, variants)(xp, **kwargs)
    for w in blk.writes:
        env[w] = out[w]


def _make_fused(seg: _Segment, program: Program, xp, variants=None):
    """The traced body: replays the segment symbolically; returns the
    store-captured values followed by the final device value of every
    block-written variable."""
    entry_pos = {k[1]: i for i, k in enumerate(seg.arg_spec)
                 if k[0] == "entry"}
    load_pos = {k[1]: i for i, k in enumerate(seg.arg_spec)
                if k[0] == "load"}
    dummy_pos = {k[1]: i for i, k in enumerate(seg.arg_spec)
                 if k[0] == "dummy"}

    def fused(*args):
        env = {v: args[i] for v, i in entry_pos.items()}
        stores: List[Any] = [None] * seg.n_stores
        for it in seg.items:
            if it[0] == "load":
                env[it[1].var] = args[load_pos[it[2]]]
            elif it[0] == "block":
                _replay_block(program.blocks[it[1]], xp, env,
                              lambda v: args[dummy_pos[v]], variants)
            elif it[0] == "store":
                stores[it[2]] = env[it[1].var]
        return tuple(stores) + tuple(env[v] for v in seg.final_writes)

    return fused


_DUMMY = "__dummy__"    # carry-key prefix for pruned (dead) declared reads


@dataclasses.dataclass
class _FusedLoop:
    """A whole loop (or a nest of pure loops) rolled into one dispatch.

    ``seg`` is the innermost body's (single, pure-device) segment; the
    carry is a dict over the segment's entry variables (+
    ``_DUMMY``-prefixed placeholders for pruned reads), and after the
    launch the final device value of every body-written variable is read
    back out of the carry.  For a nested fusion ``body_fn`` is the outer
    body (an in-trace loop over the inner body via
    ``Backend.loop_in_body``) and ``logical_iters`` is the total
    per-launch iteration multiplier (product of the nest's trip counts)
    used for logical stats parity.
    """
    loop_id: int
    n_iters: int
    seg: _Segment
    body_fn: Any            # carry dict -> carry dict, over backend.xp
    logical_iters: int = 0  # == n_iters unless nested

    def __post_init__(self):
        if not self.logical_iters:
            self.logical_iters = self.n_iters


def _make_loop_body(seg: _Segment, program: Program, xp, variants=None):
    """The per-iteration body replayed over a carry dict: blocks run in
    program order reading/writing the carry (via the same ``_replay_block``
    the segment path traces); sync items are wait points handled by the
    driver, a no-op inside the trace."""
    def body(env):
        env = dict(env)
        for it in seg.items:
            if it[0] == "block":
                _replay_block(program.blocks[it[1]], xp, env,
                              lambda v: env[_DUMMY + v], variants)
        return env
    return body


def fusable_loops(p: Plan) -> set:
    """Loop ids the compiled path will actually roll whole — the STATIC
    twin of ``_try_fuse_loop`` below (kept adjacent so the two rules
    change together; the tuner's cost model prices dispatches with it).
    A loop qualifies iff it is planner-pure AND its body is either
    blocks/syncs only (lowers to one segment) or exactly one fusable
    inner loop with nothing beside it (lowers to one nested node)."""
    pure = set(p.meta.get("pure_device_loops", ()))
    children: Dict[int, List[int]] = {}
    content: Dict[int, int] = {}
    stack: List[int] = []
    for op in p.ops:
        if op.kind == "loop_begin":
            if stack:
                children.setdefault(stack[-1], []).append(op.loop_id)
            stack.append(op.loop_id)
            children.setdefault(op.loop_id, [])
            content.setdefault(op.loop_id, 0)
        elif op.kind == "loop_end":
            stack.pop()
        elif stack and op.kind == "block":
            content[stack[-1]] += 1

    def ok(lid: int) -> bool:
        if lid not in pure:
            return False
        kids = children.get(lid, [])
        if not kids:
            return content.get(lid, 0) > 0
        return (len(kids) == 1 and content.get(lid, 0) == 0
                and ok(kids[0]))

    return {lid for lid in pure if ok(lid)}


def _make_nested_body(child: _FusedLoop, be: Backend):
    """Outer-loop body for a nested fusion: one in-trace sweep of the
    inner fused loop (``lax.fori_loop`` on device backends, a Python
    loop on numpy — backend-uniform via ``Backend.loop_in_body``)."""
    def body(env):
        return be.loop_in_body(child.body_fn, child.n_iters, env)
    return body


def _try_fuse_loop(loop_id: int, inner: List[Tuple], p: Plan,
                   be: Backend, variants=None) -> Optional[Tuple]:
    """Return a ``("fused_loop", _FusedLoop)`` node when the loop body is
    provably pure-device: the planner marked the loop invariant AND the
    body lowered to exactly one segment with blocks but no transfers —
    or to exactly one already-fused inner loop, in which case the nest
    rolls into a single nested ``fori_loop`` launch.  (The structural
    check keeps hand-mutated plans safe: a load spliced into the body
    disqualifies it regardless of the stale meta.)"""
    if loop_id not in p.meta.get("pure_device_loops", ()):
        return None
    if len(inner) != 1:
        return None
    n_iters = p.program.loops[loop_id].n_iters
    if n_iters < 1:
        return None
    if inner[0][0] == "fused_loop":
        child: _FusedLoop = inner[0][1]
        return ("fused_loop", _FusedLoop(
            loop_id=loop_id, n_iters=n_iters, seg=child.seg,
            body_fn=_make_nested_body(child, be),
            logical_iters=n_iters * child.logical_iters))
    if inner[0][0] != "seg":
        return None
    seg: _Segment = inner[0][1]
    if not seg.blocks:
        return None
    if any(it[0] in ("load", "store") for it in seg.items):
        return None
    return ("fused_loop", _FusedLoop(
        loop_id=loop_id, n_iters=n_iters, seg=seg,
        body_fn=_make_loop_body(seg, p.program, be.xp, variants)))


def _donatable(seg: _Segment) -> Tuple[int, ...]:
    """Args safe to donate: device inputs whose variable the segment
    rewrites — after the fused call the driver only keeps the new value."""
    rewritten = set(seg.final_writes)
    out = []
    for i, (tag, v) in enumerate(seg.arg_spec):
        if tag == "entry" and v in rewritten:
            out.append(i)
    return tuple(out)


# --------------------------------------------------------------------------
# Lowering: plan tree -> schedule of host blocks / segments / loops.
# --------------------------------------------------------------------------

def _lower(tree, p: Plan, be: Backend, fuse_loops: bool,
           variants=None) -> List[Tuple]:
    program = p.program
    schedule: List[Tuple] = []
    run: List[PlanOp] = []
    # vars whose host copy an in-segment op has changed (DelegateStore) or
    # invalidated (a block write): a later AdvancedLoad of such a var must
    # start a new segment, because the driver issues every upload before
    # the fused launch and would otherwise read the pre-segment host value
    # (or silently accept a host copy the interpreter rejects as stale)
    dirty_vars: set = set()

    def flush() -> None:
        nonlocal run, dirty_vars
        if run:
            seg = _build_segment(run, program)
            if seg.blocks:
                fused = _make_fused(seg, program, be.xp, variants)
                seg.fused = be.compile_fused(fused, _donatable(seg))
            schedule.append(("seg", seg))
        run, dirty_vars = [], set()

    for item in tree:
        if item[0] == "loop":
            flush()
            _, loop_id, body = item
            inner = _lower(body, p, be, fuse_loops, variants)
            node = _try_fuse_loop(loop_id, inner, p, be, variants) \
                if fuse_loops else None
            schedule.append(node or ("loop", loop_id, inner))
            continue
        op: PlanOp = item[1]
        if op.kind == "block":
            blk = program.blocks[op.block_idx]
            if blk.kind is BlockKind.HOST:
                flush()
                schedule.append(("host", blk.idx))
            else:
                run.append(op)
                dirty_vars.update(blk.writes)
            continue
        d = op.directive
        if isinstance(d, Release):
            flush()
            schedule.append(("release", d))
        elif isinstance(d, (GroupDecl, Callsite)):
            continue
        elif isinstance(d, AdvancedLoad) and d.var in dirty_vars:
            flush()          # upload must see the in-segment host state
            run.append(op)
        else:
            if isinstance(d, DelegateStore):
                dirty_vars.add(d.var)
            run.append(op)
    flush()
    return schedule


# --------------------------------------------------------------------------
# Compiled plan driver.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledPlan:
    plan: Plan
    backend: Backend
    schedule: List[Tuple]

    def run(self, env: Dict[str, _Slot], stats: ExecStats,
            check: bool) -> None:
        self._run_schedule(self.schedule, env, stats, check)

    def _run_schedule(self, schedule, env, stats, check) -> None:
        program = self.plan.program
        be = self.backend
        for item in schedule:
            kind = item[0]
            if kind == "loop":
                for _ in range(program.loops[item[1]].n_iters):
                    self._run_schedule(item[2], env, stats, check)
            elif kind == "fused_loop":
                self._run_fused_loop(item[1], env, stats, check)
            elif kind == "host":
                _run_block(program, item[1], env, stats, check, be)
            elif kind == "release":
                do_release(item[1], env, be, self.plan)
            else:
                self._run_segment(item[1], env, stats, check)

    def _run_fused_loop(self, node: _FusedLoop, env, stats: ExecStats,
                        check: bool) -> None:
        """One backend dispatch for the whole loop; logical stats still
        count every iteration (``kernel_calls``/``syncs`` scale with the
        trip count, ``fused_launches`` counts 1)."""
        be = self.backend
        seg = node.seg
        carry: Dict[str, Any] = {}
        for tag, v in seg.arg_spec:
            slot = env.setdefault(v, _Slot())
            if tag == "dummy":
                carry[_DUMMY + v] = dummy_arg(slot, be)
                continue
            if not slot.valid_device:
                if check:
                    raise PlanExecutionError(
                        f"fused loop reads {v!r}: not on device "
                        "(missing advancedload)")
                slot.device = be.upload(slot.host, name=v)
                slot.valid_device = True
            carry[v] = slot.device

        # rewritten entry vars are safe to donate: after the launch the
        # driver only keeps the carry's new value (opt-in per backend)
        donate = tuple(v for tag, v in seg.arg_spec
                       if tag == "entry" and v in seg.final_writes)
        t = time.perf_counter()
        out = be.launch_loop(node.body_fn, node.n_iters, carry,
                             donate_keys=donate)
        stats.kernel_time += time.perf_counter() - t
        stats.kernel_calls += len(seg.blocks) * node.logical_iters
        stats.fused_launches += 1

        for w in seg.final_writes:
            slot = env.setdefault(w, _Slot())
            slot.device = out[w]
            slot.valid_device, slot.valid_host = True, False

        # syncs inside the body: one real wait after the launch, counted
        # once per iteration for parity with the interpreter
        for it in seg.items:
            if it[0] == "sync":
                d = it[1]
                t = time.perf_counter()
                be.sync(d.stream)
                be.sync(0)
                stats.sync_time += time.perf_counter() - t
                stats.syncs += node.logical_iters

    def _run_segment(self, seg: _Segment, env, stats: ExecStats,
                     check: bool) -> None:
        be = self.backend
        # 1. issue every upload (async, on its directive's stream) --------
        load_handles: Dict[int, Any] = {}
        for it in seg.items:
            if it[0] == "load":
                load_handles[it[2]] = do_load(it[1], env, stats, be)

        if not seg.blocks:
            # pure transfer/sync segment: no compute to fuse
            for it in seg.items:
                if it[0] == "sync":
                    do_sync(it[1], stats, be)
                elif it[0] == "store":
                    do_store(it[1], env, stats, be)
            return

        # 2. gather fused args --------------------------------------------
        args: List[Any] = []
        for tag, v in seg.arg_spec:
            if tag == "load":
                args.append(load_handles[v])
                continue
            slot = env.setdefault(v, _Slot())
            if tag == "dummy":
                args.append(dummy_arg(slot, be))
                continue
            if not slot.valid_device:
                if check:
                    raise PlanExecutionError(
                        f"compiled segment reads {v!r}: not on device "
                        "(missing advancedload)")
                slot.device = be.upload(slot.host, name=v)
                slot.valid_device = True
            args.append(slot.device)

        # 3. one fused launch for the whole segment -----------------------
        t = time.perf_counter()
        outs = seg.fused(*args)
        stats.kernel_time += time.perf_counter() - t
        stats.kernel_calls += len(seg.blocks)   # logical count parity
        stats.fused_launches += 1
        for o in outs:
            be.track(o, stream=0)
        store_vals = outs[:seg.n_stores]
        final_map = dict(zip(seg.final_writes, outs[seg.n_stores:]))

        # 4. replay directives/flags in program order ---------------------
        for it in seg.items:
            if it[0] == "sync":
                do_sync(it[1], stats, be)
            elif it[0] == "store":
                do_store(it[1], env, stats, be, handle=store_vals[it[2]])
            elif it[0] == "block":
                blk = self.plan.program.blocks[it[1]]
                for w in blk.writes:
                    slot = env.setdefault(w, _Slot())
                    slot.device = final_map[w]
                    slot.valid_device, slot.valid_host = True, False


def compile_plan(p: Plan, backend: Backend, *,
                 fuse_loops: bool = True,
                 kernel_variants=None,
                 verify: bool = False) -> CompiledPlan:
    """Lower ``p`` for ``backend``; segments are traced/compiled lazily on
    first call by the backend's compiler (``jax.jit`` caches thereafter).
    ``fuse_loops=False`` keeps eligible loops as per-iteration segment
    dispatches (the PR-1 behaviour) — useful for benchmarking the
    whole-loop lowering win in isolation.  ``kernel_variants`` binds tile
    parameters onto kernel-tagged blocks inside the traced bodies (see
    ``execute``).  ``verify=True`` statically vets the plan
    (``repro.core.verify``) before lowering — donation safety is judged
    against this backend's donation flag — and raises
    ``PlanVerificationError`` instead of compiling a broken schedule."""
    if verify:
        from .verify import verify_plan
        donating = (bool(getattr(backend, "supports_donation", False))
                    and bool(getattr(backend, "donate", False)))
        verify_plan(p, donate=donating,
                    kernel_variants=kernel_variants or None,
                    collect_lints=False).raise_if_failed()
    tree = _nest(p.ops, p.program)
    schedule = _lower(tree, p, backend, fuse_loops, kernel_variants)
    return CompiledPlan(plan=p, backend=backend, schedule=schedule)
