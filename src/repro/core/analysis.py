"""Dataflow analysis over a ``Program`` — the paper's AST analysis, on jaxprs.

OMP2HMPP walks Mercurium's AST to find, for every variable used by a codelet:
its io direction (``in``/``out``/``inout``), the *last CPU write* before the
callsite and the *first CPU read* after it, with loop-nesting context
(paper §2, Figs. 1-3).  Here each block body is traced to a jaxpr (via
``jax.eval_shape`` / ``jax.make_jaxpr``), which gives us exact def/use:
declared reads that do not appear in the jaxpr are pruned — the analogue of
the paper noticing that 3MM's kernel never *reads* E before writing it, so E
needs no upload.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.extend.core as jex_core
import jax.numpy as jnp
import numpy as np

from .ir import Block, BlockKind, Program, VarIO

__all__ = [
    "ProgramAnalysis", "analyze", "common_prefix", "hoist_target",
    "abstractify",
]


def abstractify(x: Any) -> jax.ShapeDtypeStruct:
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    arr = np.asarray(x)
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def common_prefix(a: Tuple[int, ...], b: Tuple[int, ...]) -> Tuple[int, ...]:
    out = []
    for x, y in zip(a, b):
        if x != y:
            break
        out.append(x)
    return tuple(out)


def hoist_target(src_path: Tuple[int, ...], dst_path: Tuple[int, ...]
                 ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Where a directive tied to a block at ``src_path`` must sit so that it is
    visible to a block at ``dst_path`` exactly once per shared iteration.

    Returns (placement_path, hoisted_loops): the loop path the directive
    should live at (the common prefix of the two paths — paper Fig. 2/3) and
    the loops of ``src_path`` it was hoisted out of.
    """
    shared = common_prefix(src_path, dst_path)
    return shared, src_path[len(shared):]


@dataclasses.dataclass
class VarEvent:
    """One def or use of a variable by a block."""
    block_idx: int
    is_write: bool
    kind: BlockKind
    loop_path: Tuple[int, ...]


@dataclasses.dataclass
class ProgramAnalysis:
    program: Program
    shapes: Dict[str, jax.ShapeDtypeStruct]           # var -> abstract value
    events: Dict[str, List[VarEvent]]                 # var -> ordered events
    io_table: Dict[int, Dict[str, VarIO]]             # offload blk -> var io
    groups: Dict[int, Tuple[int, ...]]                # group -> blk idxs
    group_of: Dict[int, int]                          # offload blk -> group

    # -- the queries the planner asks (paper §2) ---------------------------
    def last_host_write_before(self, var: str, idx: int) -> Optional[VarEvent]:
        best = None
        for ev in self.events.get(var, ()):
            if ev.block_idx >= idx:
                break
            if ev.is_write and ev.kind is BlockKind.HOST:
                best = ev
        return best

    def last_write_before(self, var: str, idx: int) -> Optional[VarEvent]:
        best = None
        for ev in self.events.get(var, ()):
            if ev.block_idx >= idx:
                break
            if ev.is_write:
                best = ev
        return best

    def first_host_read_after(self, var: str, idx: int) -> Optional[VarEvent]:
        """First host READ of ``var`` after block ``idx``, or None if the
        value is overwritten first (write events for inout blocks are emitted
        *after* the matching read event, so ordering handles inout)."""
        for ev in self.events.get(var, ()):
            if ev.block_idx <= idx:
                continue
            if not ev.is_write and ev.kind is BlockKind.HOST:
                return ev
            if ev.is_write:
                # value produced at `idx` is dead past this point
                return None
        return None

    def last_carried_write(self, var: str, blk) -> Optional[VarEvent]:
        """The loop-carried dynamic predecessor write: the max-idx write of
        ``var`` textually AFTER ``blk`` that shares an enclosing loop with
        it — in iterations ≥ 2 this write (from the previous iteration) is
        the freshest value at ``blk``.  None if no such write."""
        if not blk.loop_path:
            return None
        enclosing = set(blk.loop_path)
        best = None
        for ev in self.events.get(var, ()):
            if ev.block_idx > blk.idx and ev.is_write \
                    and enclosing & set(ev.loop_path):
                best = ev
        return best

    def carried_host_read(self, var: str, blk) -> Optional[VarEvent]:
        """A host read of ``var`` textually BEFORE ``blk`` sharing a loop —
        in iterations ≥ 2 it consumes the value ``blk`` wrote in the
        previous iteration (unless another write intervenes at the start of
        the body, which the plan simulation then handles)."""
        if not blk.loop_path:
            return None
        enclosing = set(blk.loop_path)
        for ev in self.events.get(var, ()):
            if ev.block_idx >= blk.idx:
                break
            if not ev.is_write and ev.kind is BlockKind.HOST \
                    and enclosing & set(ev.loop_path):
                return ev
        return None

    def reads_between(self, var: str, lo: int, hi: int,
                      kind: Optional[BlockKind] = None) -> List[VarEvent]:
        out = []
        for ev in self.events.get(var, ()):
            if lo < ev.block_idx < hi and not ev.is_write:
                if kind is None or ev.kind is kind:
                    out.append(ev)
        return out

    def host_write_between(self, var: str, lo: int, hi: int) -> bool:
        for ev in self.events.get(var, ()):
            if lo < ev.block_idx < hi and ev.is_write \
                    and ev.kind is BlockKind.HOST:
                return True
        return False


def _traced_reads(block: Block, env_shapes: Dict[str, jax.ShapeDtypeStruct]
                  ) -> Tuple[Tuple[str, ...], Dict[str, jax.ShapeDtypeStruct]]:
    """Trace the block body; return (vars actually read, shapes written)."""
    names = [v for v in block.reads if v in env_shapes]
    missing = [v for v in block.reads if v not in env_shapes]
    if missing:
        raise ValueError(
            f"block {block.name!r} reads undefined vars {missing}")
    in_avals = [env_shapes[v] for v in names]

    def wrapped(*arrays):
        out = block.fn(jnp, **dict(zip(names, arrays)))
        if not isinstance(out, dict):
            raise TypeError(
                f"block {block.name!r} must return a dict of writes")
        return tuple(out[w] for w in block.writes)

    jaxpr = jax.make_jaxpr(wrapped)(*in_avals)
    # an input is actually read iff its invar is used by an eqn or returned
    used_vars = set()
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.invars:
            if not isinstance(v, jex_core.Literal):
                used_vars.add(v)
        # look inside closed sub-jaxprs conservatively: invars of the eqn
        # already cover data flowing in, so nothing extra needed.
    for v in jaxpr.jaxpr.outvars:
        if not isinstance(v, jex_core.Literal):
            used_vars.add(v)
    actual = tuple(
        name for name, invar in zip(names, jaxpr.jaxpr.invars)
        if invar in used_vars
    )
    out_shapes = {
        w: jax.ShapeDtypeStruct(ov.aval.shape, ov.aval.dtype)
        for w, ov in zip(block.writes, jaxpr.jaxpr.outvars)
    }
    return actual, out_shapes


def analyze(program: Program) -> ProgramAnalysis:
    """Run the paper's §2 analysis: io classification + def/use timeline."""
    shapes: Dict[str, jax.ShapeDtypeStruct] = {
        k: abstractify(v) for k, v in program.inputs.items()
    }
    events: Dict[str, List[VarEvent]] = {}

    def add_event(var, blk, is_write):
        events.setdefault(var, []).append(
            VarEvent(blk.idx, is_write, blk.kind, blk.loop_path))

    for blk in program.blocks:
        actual, out_shapes = _traced_reads(blk, shapes)
        blk.actual_reads = actual
        for v in actual:
            add_event(v, blk, is_write=False)
        for v in blk.writes:
            add_event(v, blk, is_write=True)
        shapes.update(out_shapes)

    # io classification per offload block (paper: args[x].io=...)
    io_table: Dict[int, Dict[str, VarIO]] = {}
    for blk in program.offload_blocks():
        table: Dict[str, VarIO] = {}
        reads, writes = set(blk.effective_reads()), set(blk.writes)
        for v in reads | writes:
            if v in reads and v in writes:
                table[v] = VarIO.INOUT
            elif v in writes:
                table[v] = VarIO.OUT
            else:
                table[v] = VarIO.IN
        io_table[blk.idx] = table

    # grouping: union-find over offload blocks sharing any variable
    parent: Dict[int, int] = {b.idx: b.idx for b in program.offload_blocks()}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)

    touched: Dict[str, int] = {}
    for blk in program.offload_blocks():
        for v in set(blk.effective_reads()) | set(blk.writes):
            if v in touched:
                union(touched[v], blk.idx)
            else:
                touched[v] = blk.idx

    roots = sorted({find(b.idx) for b in program.offload_blocks()})
    root_to_group = {r: g for g, r in enumerate(roots)}
    group_of = {b.idx: root_to_group[find(b.idx)]
                for b in program.offload_blocks()}
    groups = {
        g: tuple(b.idx for b in program.offload_blocks()
                 if group_of[b.idx] == g)
        for g in root_to_group.values()
    }

    return ProgramAnalysis(
        program=program, shapes=shapes, events=events,
        io_table=io_table, groups=groups, group_of=group_of,
    )
