"""Pluggable execution backends for the plan runtime.

The paper's generated HMPP code targets a CPU+GPU pair with asynchronous
queues; our executor used to hard-code "host = numpy, device = default JAX
device, every transfer blocks".  This module factors that choice out into a
``Backend`` protocol — alloc/upload/download/launch/sync plus per-stream
events — so the same ``Plan`` can run against:

``NumpyHostBackend``
    Both spaces are numpy.  Transfers are copies, launches run the block
    body with ``numpy``.  Useful for validating plans (the residency
    discipline is still enforced by the driver) without touching JAX.

``JaxDeviceBackend``
    Device space is the default JAX device.  ``upload`` is an async
    ``jax.device_put`` enqueued on one of ``n_streams`` logical transfer
    streams (double-buffered by default), launches are jitted and dispatch
    asynchronously, and ``sync(stream)`` is a *real* wait point: it blocks
    on every event outstanding on that stream.  Buffer donation for
    fused launches is ON by default (the serving engine's decode path
    exercises it every step); construct with ``donate=False`` to opt
    out.

``PinnedHostBackend``
    Same as ``JaxDeviceBackend`` but the host side of every transfer is
    staged in ``pinned_host`` device memory when the platform supports it
    (see ``repro.optim.offload.host_memory_kind``), which is what makes
    h2d genuinely overlappable on TPU.  Falls back to plain
    ``JaxDeviceBackend`` behaviour on platforms without a pinned space
    (e.g. CPU jaxlib builds).

Streams are logical ids chosen by the planner (``AdvancedLoad.stream``
etc.); a backend may map many logical streams onto fewer physical ones
(``stream % n_streams``).  Stream 0 is the compute stream by convention.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Backend", "Event", "NumpyHostBackend", "JaxDeviceBackend",
    "PinnedHostBackend", "get_backend", "register_backend",
]


@dataclasses.dataclass
class Event:
    """Completion handle for an async backend operation.

    ``payload`` is whatever must be ready before the op is complete (a
    jax.Array for device backends, nothing for numpy).  ``wait()`` is
    idempotent.
    """
    payload: Any = None
    _done: bool = False

    def wait(self) -> None:
        if self._done:
            return
        if self.payload is not None and hasattr(self.payload,
                                                "block_until_ready"):
            try:
                self.payload.block_until_ready()
            except RuntimeError:
                pass   # buffer deleted/donated since: nothing left to wait on
        self._done = True


class Backend:
    """Protocol for plan-execution backends (duck-typed; subclass for the
    shared stream bookkeeping).

    Handles returned by ``upload``/``launch`` are opaque to the driver; it
    only stores them in slots and passes them back in.
    """

    name: str = "abstract"
    n_streams: int = 2   # logical transfer streams (double-buffered)
    supports_donation: bool = False   # can ``donate=True`` change execution?

    def __init__(self) -> None:
        self._pending: Dict[int, List[Event]] = {}
        self.loop_dispatches = 0   # fused whole-loop launches (launch_loop)

    def variant(self, *, n_streams: Optional[int] = None,
                donate: Optional[bool] = None) -> "Backend":
        """A backend identical to this one except for the given knobs —
        the tuner uses it to measure each candidate on a PHYSICALLY
        matching backend (a streams-3 plan on a 3-queue backend, a
        donate candidate on a donating one) instead of folding every
        config onto the caller's instance.  Backends without the knob
        return themselves; implementations must memoize twins so jit /
        lowering caches are shared across tuning calls."""
        return self

    @property
    def xp(self):
        """Array namespace block bodies run under (numpy or jax.numpy)."""
        raise NotImplementedError

    # -- stream/event bookkeeping (shared) ---------------------------------
    _MAX_PENDING = 64     # per stream; oldest events are drained past this

    def _stream_of(self, stream: int) -> int:
        """Logical → physical stream.  Stream 0 (compute) is reserved;
        transfer streams 1..∞ fold onto the backend's 1..n_streams so
        they never collide with the compute queue."""
        if stream <= 0:
            return 0
        return 1 + (stream - 1) % max(self.n_streams, 1)

    def _record(self, stream: int, ev: Event) -> Event:
        q = self._pending.setdefault(self._stream_of(stream), [])
        q.append(ev)
        # bound the queue so callers that never sync (e.g. a residency
        # prefetch loop) don't pin every in-flight array forever
        while len(q) > self._MAX_PENDING:
            q.pop(0).wait()
        return ev

    def sync(self, stream: Optional[int] = None) -> None:
        """Block until every event on ``stream`` (or all streams) is done."""
        keys = (list(self._pending) if stream is None
                else [self._stream_of(stream)])
        for k in keys:
            for ev in self._pending.pop(k, ()):
                ev.wait()

    def track(self, handle: Any, *, stream: int = 0) -> Any:
        """Register an externally produced handle (e.g. a fused-launch
        output) so a later ``sync(stream)`` waits on it."""
        self._record(stream, Event(payload=handle))
        return handle

    # -- memory ------------------------------------------------------------
    def alloc(self, shape: Tuple[int, ...], dtype) -> Any:
        """Fresh zero device buffer (used for pruned/dead block inputs)."""
        raise NotImplementedError

    def upload(self, host: np.ndarray, *, stream: int = 0,
               name: Optional[str] = None) -> Any:
        """h2d: returns a device handle; completion tracked on ``stream``.

        ``name`` is the plan variable being uploaded — mesh/sharded
        backends key per-variable placements on it (``MeshBackend``
        shards or replicates by name); single-device backends ignore
        it."""
        raise NotImplementedError

    def download(self, handle: Any, *, stream: int = 0) -> np.ndarray:
        """d2h: returns a host ndarray (a wait point for ``handle``)."""
        raise NotImplementedError

    def free(self, handle: Any) -> None:
        """Release a device handle (HMPP ``release``).  Events waiting on
        the handle are retired first so a later ``sync`` never blocks on
        a deleted buffer."""
        for q in self._pending.values():
            for ev in q:
                if ev.payload is handle:
                    ev.payload, ev._done = None, True

    # -- compute -----------------------------------------------------------
    def launch(self, fn: Callable[..., Dict[str, Any]],
               names: Sequence[str], writes: Sequence[str],
               args: Sequence[Any], *, stream: int = 0) -> Tuple[Any, ...]:
        """Run one offload block body; returns device handles for
        ``writes`` in order.  Dispatch may be asynchronous."""
        raise NotImplementedError

    def compile_fused(self, fused_fn: Callable[..., Tuple[Any, ...]],
                      donate_argnums: Tuple[int, ...] = ()
                      ) -> Callable[..., Tuple[Any, ...]]:
        """Lower a fused segment function (see ``core.compile``) to this
        backend's compiled form.  ``donate_argnums`` marks inputs the
        caller will not reuse; backends may ignore it.  Default: eager."""
        return fused_fn

    def launch_loop(self, body_fn: Callable[[Dict[str, Any]],
                                            Dict[str, Any]],
                    n_iters: int, carry: Dict[str, Any],
                    *, stream: int = 0,
                    donate_keys: Sequence[str] = ()) -> Dict[str, Any]:
        """Whole-loop launch: run ``carry = body_fn(carry)`` ``n_iters``
        times as ONE backend dispatch and return the final carry.

        ``carry`` maps loop-state names to device handles; ``body_fn`` is
        pure (built by ``core.compile`` over ``self.xp``) and returns a
        carry with the same keys plus any body-defined variables, whose
        values stabilize in shape/dtype after the first iteration.  Device
        backends lower this to a single jitted ``lax.fori_loop``
        (body-defined state is zero-initialized from ``jax.eval_shape`` —
        NOT peeled: a peeled iteration compiles in a different XLA context
        than the while body and breaks bitwise parity); the numpy backend
        runs a Python loop inside the one dispatch, keeping the contract
        backend-uniform.  ``loop_dispatches`` counts calls.

        ``donate_keys`` names carry entries whose pre-launch buffers the
        caller will not reuse (rewritten loop state — the fused-loop
        analogue of segment arg donation); backends may donate them to
        the launch.  Opt-in: only backends constructed with
        ``donate=True`` act on it.
        """
        if n_iters < 1:
            raise ValueError("launch_loop needs n_iters >= 1")
        self.loop_dispatches += 1
        return self._launch_loop(body_fn, n_iters, carry, stream=stream,
                                 donate_keys=tuple(donate_keys))

    def _launch_loop(self, body_fn, n_iters: int, carry: Dict[str, Any],
                     *, stream: int = 0,
                     donate_keys: Tuple[str, ...] = ()) -> Dict[str, Any]:
        raise NotImplementedError

    def loop_in_body(self, body_fn: Callable[[Dict[str, Any]],
                                             Dict[str, Any]],
                     n_iters: int, env: Dict[str, Any]) -> Dict[str, Any]:
        """Run ``env = body_fn(env)`` ``n_iters`` times INSIDE a trace —
        the primitive nested fused loops are built from (the outer loop's
        body is ``loop_in_body`` over the inner one).  Default: a plain
        Python loop (numpy, or any eager backend).  Device backends
        override it with an in-trace ``lax.fori_loop``."""
        for _ in range(n_iters):
            env = body_fn(env)
        return env


class NumpyHostBackend(Backend):
    """Both memory spaces are numpy; the device is simulated with copies so
    residency bugs (reading a stale space) still surface as wrong counts."""

    name = "numpy"

    @property
    def xp(self):
        return np

    def alloc(self, shape, dtype):
        return np.zeros(shape, dtype)

    def upload(self, host, *, stream: int = 0, name=None):
        handle = np.array(host, copy=True)
        self._record(stream, Event(payload=None, _done=True))
        return handle

    def download(self, handle, *, stream: int = 0):
        return np.array(handle, copy=True)

    def launch(self, fn, names, writes, args, *, stream: int = 0):
        out = fn(np, **dict(zip(names, args)))
        self._record(stream, Event(payload=None, _done=True))
        return tuple(np.asarray(out[w]) for w in writes)

    def compile_fused(self, fused_fn, donate_argnums=()):
        return fused_fn            # no tracing: eager numpy

    def _launch_loop(self, body_fn, n_iters, carry, *, stream: int = 0,
                     donate_keys=()):
        for _ in range(n_iters):
            carry = body_fn(carry)
        self._record(stream, Event(payload=None, _done=True))
        return carry


@functools.lru_cache(maxsize=512)
def _jitted_block(fn, names: Tuple[str, ...], writes: Tuple[str, ...]):
    import jax
    import jax.numpy as jnp

    def wrapped(*arrays):
        out = fn(jnp, **dict(zip(names, arrays)))
        return tuple(out[w] for w in writes)
    return jax.jit(wrapped)


class JaxDeviceBackend(Backend):
    """Default JAX device space, async transfers on logical streams."""

    name = "jax"
    supports_donation = True

    # Donation defaults ON (ISSUE 8): the serve decode path donates the
    # pooled KV cache every step, and the tuner always measures donate
    # candidates on an explicit ``variant(donate=...)`` twin, so the
    # default only affects direct ``execute()`` callers — whose inputs
    # are re-uploaded from host per call and never alias a donated
    # buffer.  ``donate=False`` is the explicit opt-out.
    def __init__(self, device=None, *, n_streams: int = 2,
                 donate: bool = True):
        super().__init__()
        import jax
        self._jax = jax
        self._device = device if device is not None else jax.devices()[0]
        self.n_streams = n_streams
        self.donate = donate
        # (n_streams, donate) -> twin; shared by every twin of this
        # device so variant-of-variant returns the original instance
        self._variant_pool: Dict[Tuple[int, bool], "JaxDeviceBackend"] = {
            (n_streams, donate): self}

    def variant(self, *, n_streams: Optional[int] = None,
                donate: Optional[bool] = None) -> "JaxDeviceBackend":
        ns = self.n_streams if n_streams is None else max(1, int(n_streams))
        dn = self.donate if donate is None else bool(donate)
        twin = self._variant_pool.get((ns, dn))
        if twin is None:
            twin = type(self)(device=self._device, n_streams=ns, donate=dn)
            twin._variant_pool = self._variant_pool
            self._variant_pool[(ns, dn)] = twin
        return twin

    @property
    def xp(self):
        import jax.numpy as jnp
        return jnp

    # host-side staging sharding for transfers; None = plain device_put
    def _host_space(self):
        return None

    def alloc(self, shape, dtype):
        import jax.numpy as jnp
        return jnp.zeros(shape, dtype)

    def upload(self, host, *, stream: int = 0, name=None):
        handle = self._jax.device_put(host, self._device)   # async dispatch
        self._record(stream, Event(payload=handle))
        return handle

    def download(self, handle, *, stream: int = 0):
        staged = self._host_space()
        if staged is not None:
            handle = self._jax.device_put(handle, staged)
        return np.asarray(handle)                           # wait point

    def free(self, handle) -> None:
        super().free(handle)       # retire events waiting on this buffer
        if hasattr(handle, "delete"):
            try:
                handle.delete()
            except Exception:
                pass   # buffer may be donated/shared; dropping the ref wins

    def launch(self, fn, names, writes, args, *, stream: int = 0):
        outs = _jitted_block(fn, tuple(names), tuple(writes))(*args)
        for o in outs:
            self._record(stream, Event(payload=o))
        return outs

    def compile_fused(self, fused_fn, donate_argnums=()):
        if donate_argnums and self.donate:
            return self._jax.jit(fused_fn, donate_argnums=donate_argnums)
        return self._jax.jit(fused_fn)

    def loop_in_body(self, body_fn, n_iters, env):
        """In-trace whole loop — THE single fencing/zero-init discipline
        both the flat `_launch_loop` and nested fusion build on.

        optimization_barrier fences each iteration: without it XLA
        hoists loop-invariant work (CSE/LICM) and re-fuses across
        iterations, which changes FMA rounding and breaks the
        bitwise-equality contract with the per-iteration interpreted/
        segment paths.  Body-defined carry slots (written before any
        read on every valid plan) are discovered abstractly and
        zero-initialized, so EVERY iteration runs inside the fori_loop —
        peeling iteration 0 to top level instead would compile it in a
        different XLA context than the while body and break bitwise
        equality (seen on CPU)."""
        jax = self._jax
        import jax.numpy as jnp

        def one_iter(e):
            e = jax.lax.optimization_barrier(dict(e))
            return jax.lax.optimization_barrier(dict(body_fn(e)))

        shapes = jax.eval_shape(body_fn, env)
        env = dict(env)
        for k, sd in shapes.items():
            if k not in env:
                env[k] = jnp.zeros(sd.shape, sd.dtype)
        return jax.lax.fori_loop(0, n_iters, lambda i, e: one_iter(e), env)

    def _launch_loop(self, body_fn, n_iters, carry, *, stream: int = 0,
                     donate_keys=()):
        # the jitted whole-loop is cached ON body_fn so it lives exactly
        # as long as the compiled plan that owns the closure (a cache on
        # the backend would pin every program forever: the jit references
        # body_fn, so a backend-held mapping entry could never be freed)
        per_fn = getattr(body_fn, "_launch_loop_cache", None)
        if per_fn is None:
            per_fn = body_fn._launch_loop_cache = {}
        dkeys = (tuple(sorted(k for k in donate_keys if k in carry))
                 if self.donate else ())
        jitted = per_fn.get((n_iters, dkeys))
        if jitted is None:
            def whole(donated, kept):
                env = dict(kept)
                env.update(donated)
                return self.loop_in_body(body_fn, n_iters, env)

            # rewritten loop state is donated to the launch (the caller
            # only keeps the final carry), mirroring segment donation
            jitted = self._jax.jit(whole,
                                   donate_argnums=(0,) if dkeys else ())
            per_fn[(n_iters, dkeys)] = jitted
        donated = {k: carry[k] for k in dkeys}
        kept = {k: v for k, v in carry.items() if k not in dkeys}
        out = jitted(donated, kept)
        for v in out.values():
            self._record(stream, Event(payload=v))
        return out


class PinnedHostBackend(JaxDeviceBackend):
    """JAX backend whose transfers stage through ``pinned_host`` memory —
    the ``optim/offload.py`` machinery applied to the block executor.  On
    platforms with no pinned space this degrades to ``JaxDeviceBackend``
    (the logical plan semantics are unchanged either way)."""

    name = "pinned"

    def __init__(self, device=None, *, n_streams: int = 2,
                 donate: bool = True):
        super().__init__(device, n_streams=n_streams, donate=donate)
        from repro.optim.offload import host_memory_kind
        kind = host_memory_kind(self._device)
        self._pinned_sharding = None
        if kind is not None:
            self._pinned_sharding = (
                self._jax.sharding.SingleDeviceSharding(self._device)
                .with_memory_kind(kind))

    def _host_space(self):
        return self._pinned_sharding

    def upload(self, host, *, stream: int = 0, name=None):
        if self._pinned_sharding is not None:
            host = self._jax.device_put(host, self._pinned_sharding)
        return super().upload(host, stream=stream, name=name)


_REGISTRY: Dict[str, Callable[[], Backend]] = {
    "numpy": NumpyHostBackend,
    "jax": JaxDeviceBackend,
    "pinned": PinnedHostBackend,
}


def register_backend(name: str, factory: Callable[[], Backend]) -> None:
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


_INSTANCES: Dict[str, Backend] = {}


def get_backend(spec: Any = None) -> Backend:
    """Resolve a backend: an instance passes through; ``None`` or a
    registered name returns a memoized process-wide instance — so jit
    caches and compiled-plan lowerings are reused across ``execute``
    calls no matter how the backend was named."""
    if isinstance(spec, Backend):
        return spec
    if spec is None:
        spec = "jax"
    if spec not in _INSTANCES:
        if spec == "mesh" and "mesh" not in _REGISTRY:
            # registered on import (distributed code never loads for
            # single-device callers otherwise)
            from repro.distributed import mesh_backend  # noqa: F401
        try:
            factory = _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown backend {spec!r}; have "
                f"{sorted(_REGISTRY)}") from None
        _INSTANCES[spec] = factory()
    return _INSTANCES[spec]
