"""OMP2HMPP-style offload planning for JAX — the paper's core contribution.

Public API:
    Program          — block/loop program builder (the "pragma'd source")
    analyze          — jaxpr def/use + liveness analysis (paper §2)
    plan             — optimized directive placement (advancedload ASAP,
                       delegatestore ALAP, noupdate, groups, async+sync,
                       per-group transfer streams)
    naive_plan       — the paper's baseline policy (Figs. 4a/5a)
    execute          — instrumented driver over pluggable backends;
                       mode="interpreted" | "compiled"
    compile_plan     — lower a Plan to a fused jit-compiled schedule
    Backend et al.   — the execution backends (numpy / jax / pinned)
    run_host_oracle  — pure-host reference semantics
    emit             — HMPP-style generated source (paper Table 2)
    verify_plan      — static race / transfer-consistency / donation-safety
                       checker run at every plan boundary (hard error)
    DeviceResidency  — runtime residency tracker for the training substrates
"""
from .analysis import ProgramAnalysis, analyze
from .backend import (Backend, Event, JaxDeviceBackend, NumpyHostBackend,
                      PinnedHostBackend, get_backend, register_backend)
from .compile import CompiledPlan, compile_plan
from .emitter import emit
from .executor import ExecStats, PlanExecutionError, execute, run_host_oracle
from .ir import (AdvancedLoad, Block, BlockKind, Callsite, DelegateStore,
                 GroupDecl, Plan, PlanOp, Program, Release, Synchronize,
                 VarIO)
from .passes import (Pass, Pipeline, PlanDraft, get_placement,
                     placement_names, register_placement)
from .planner import naive_plan, plan, transfer_summary
from .residency import (DeviceResidency, ResidencyStats,
                        plan_peak_device_bytes)
from .tunecache import (COST_MODEL_VERSION, TuneCache, backend_fingerprint,
                        default_cache, device_class_key, program_fingerprint,
                        tuning_fingerprint)
from .tuner import (OBJECTIVES, PlanConfig, pareto_front, predict_cost, tune,
                    winner_exec_kwargs)
from .verify import (PlanVerificationError, VerifyReport, Violation,
                     verify_plan)

__all__ = [
    "Program", "Block", "BlockKind", "VarIO", "Plan", "PlanOp",
    "AdvancedLoad", "DelegateStore", "Callsite", "Synchronize", "Release",
    "GroupDecl",
    "ProgramAnalysis", "analyze", "plan", "naive_plan", "transfer_summary",
    "execute", "run_host_oracle", "ExecStats", "PlanExecutionError",
    "compile_plan", "CompiledPlan",
    "Backend", "Event", "NumpyHostBackend", "JaxDeviceBackend",
    "PinnedHostBackend", "get_backend", "register_backend",
    "emit", "DeviceResidency", "ResidencyStats",
    "Pass", "Pipeline", "PlanDraft",
    "register_placement", "get_placement", "placement_names",
    "PlanConfig", "predict_cost", "tune", "winner_exec_kwargs",
    "OBJECTIVES", "pareto_front", "plan_peak_device_bytes",
    "TuneCache", "COST_MODEL_VERSION", "default_cache",
    "program_fingerprint", "backend_fingerprint", "tuning_fingerprint",
    "device_class_key",
    "verify_plan", "VerifyReport", "Violation", "PlanVerificationError",
]
