"""Plan executor: a thin driver over pluggable backends.

The paper's generated HMPP code runs on CPU+GPU; here "host" is numpy and
"device" is whatever ``Backend`` the caller picks (``repro.core.backend``):
the default JAX device space, a ``pinned_host``-staged variant, or a pure
numpy simulation.  The driver walks a ``Plan``, runs host blocks with
numpy, dispatches offload blocks and transfers through the backend ONLY
where the plan says so — transfer counts/bytes/wall times are recorded,
which is exactly what the paper's Figs. 4-6 measure.

Two execution modes:

``mode="interpreted"``
    Walk the plan tree op by op (the original semantics; every directive
    is dispatched through Python each time it is reached).

``mode="compiled"``
    Lower the plan once via ``repro.core.compile``: runs of offload blocks
    and their directives become fused segments whose bodies are traced and
    jitted a single time, so loop iterations re-enter compiled code
    instead of the Python dispatch loop.  Outputs are bitwise-identical to
    interpreted mode and the *logical* transfer counts in ``ExecStats``
    match; only the wall-time fields change (that is the point).

The driver also *verifies* the plan: reading a variable from a space with
no valid copy raises ``PlanExecutionError`` (the property tests drive
random programs through this).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from .backend import Backend, get_backend
from .ir import (AdvancedLoad, BlockKind, Callsite, DelegateStore, GroupDecl,
                 Plan, PlanExecutionError, PlanOp, Program, Release,
                 Synchronize)

__all__ = ["execute", "run_host_oracle", "ExecStats", "PlanExecutionError",
           "group_vars", "kernel_fn"]


@dataclasses.dataclass
class ExecStats:
    h2d_transfers: int = 0
    h2d_bytes: int = 0
    d2h_transfers: int = 0
    d2h_bytes: int = 0
    kernel_calls: int = 0       # logical block launches (also in compiled)
    host_calls: int = 0
    syncs: int = 0
    fused_launches: int = 0     # compiled mode: actual jit invocations
    h2d_time: float = 0.0
    d2h_time: float = 0.0
    kernel_time: float = 0.0
    host_time: float = 0.0
    sync_time: float = 0.0
    wall_time: float = 0.0
    compile_time: float = 0.0   # one-time plan lowering (compiled mode);
                                # NOT folded into wall_time

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def transfer_counts(self) -> Dict[str, int]:
        """The mode-invariant logical schedule: what the plan *did*."""
        return {"h2d_transfers": self.h2d_transfers,
                "h2d_bytes": self.h2d_bytes,
                "d2h_transfers": self.d2h_transfers,
                "d2h_bytes": self.d2h_bytes,
                "kernel_calls": self.kernel_calls,
                "host_calls": self.host_calls,
                "syncs": self.syncs}


@dataclasses.dataclass
class _Slot:
    host: Optional[np.ndarray] = None
    device: Optional[Any] = None          # backend-opaque handle
    valid_host: bool = False
    valid_device: bool = False


def _nbytes(x) -> int:
    return int(np.prod(np.shape(x))) * np.dtype(x.dtype).itemsize


def _kv_norm(kv) -> Dict[str, Dict[str, int]]:
    """Canonical {kernel: {param: int}} view of a kernel-variants mapping
    (accepts the tuple-of-pairs form KernelVariant/JSON round-trips use)."""
    if not kv:
        return {}
    return {str(k): {str(n): int(v) for n, v in dict(params).items()}
            for k, params in dict(kv).items()}


def _kv_key(kv: Dict[str, Dict[str, int]]):
    """Hashable identity of a variant choice (compiled-plan cache key)."""
    return tuple(sorted((k, tuple(sorted(p.items())))
                        for k, p in kv.items()))


def kernel_fn(blk, variants: Optional[Dict[str, Dict[str, int]]] = None):
    """The callable to launch for ``blk``: kernel-tagged blocks get their
    chosen tile parameters bound as keyword arguments (memoized partials,
    so backend jit caches keyed on fn identity still hit); every other
    block launches ``blk.fn`` unchanged."""
    if getattr(blk, "kernel", None) and variants:
        params = variants.get(blk.kernel)
        if params:
            from repro.kernels.variants import bind_variant
            return bind_variant(blk.fn, tuple(sorted(params.items())))
    return blk.fn


def _verify_default() -> bool:
    """``execute(..., verify=None)`` resolves through the ``REPRO_VERIFY``
    env gate (CI sets it to 1 so every executed plan is statically vetted
    first)."""
    return os.environ.get("REPRO_VERIFY", "").strip().lower() in (
        "1", "true", "on", "yes")


def execute(p: Plan, inputs: Optional[Dict[str, np.ndarray]] = None,
            *, check: bool = True, mode: str = "interpreted",
            backend: Any = None, fuse_loops: Optional[bool] = None,
            kernel_variants: Optional[Dict[str, Dict[str, int]]] = None,
            verify: Optional[bool] = None
            ) -> Tuple[Dict[str, np.ndarray], ExecStats]:
    """Run the plan; return (program outputs on host, stats).

    ``mode`` is "interpreted" or "compiled"; ``backend`` is a
    ``Backend`` instance, a registered name ("jax", "pinned", "numpy"),
    or None for the default JAX device backend.  ``fuse_loops`` (compiled
    mode only) rolls eligible pure-device loops into a single backend
    dispatch (``lax.fori_loop``); disable it to benchmark the
    per-iteration segment path.  When left None it follows the plan:
    a tuned winner carries its chosen flag in ``meta["fuse_loops"]``
    (default True), so executing a ``policy="auto"`` plan directly runs
    the variant the tuner measured (donation still needs the matching
    backend — use ``winner_exec_kwargs``).

    ``kernel_variants`` maps kernel names to tile parameters
    ({"flash_attention": {"block_q": 128, "block_k": 64}}) for
    kernel-tagged blocks; when left None it follows the plan
    (``meta["kernel_variants"]``, set by the tuner's winner), so a tuned
    plan launches the winning tile sizes by default.

    ``verify`` runs the static plan verifier (``repro.core.verify``)
    before executing and raises ``PlanVerificationError`` on any race /
    transfer-consistency / donation-safety error; ``None`` follows the
    ``REPRO_VERIFY=1`` environment gate (set in CI).

    One-time plan-lowering cost is reported as ``stats.compile_time`` and
    excluded from ``stats.wall_time``, so first-call and steady-state runs
    report comparable wall times.
    """
    if mode not in ("interpreted", "compiled"):
        raise ValueError(f"unknown execution mode {mode!r}")
    if fuse_loops is None:
        fuse_loops = bool(p.meta.get("fuse_loops", True))
    if kernel_variants is None:
        kernel_variants = p.meta.get("kernel_variants")
    kernel_variants = _kv_norm(kernel_variants)
    be = get_backend(backend)
    # a mesh-tuned plan carries its winning per-variable placement in
    # meta["mesh"]; re-apply it on any placement-capable backend so
    # executing the winner directly shards exactly as measured
    mesh_meta = p.meta.get("mesh")
    if mesh_meta and hasattr(be, "with_placement"):
        be = be.with_placement(mesh_meta.get("specs") or {})
    if verify is None:
        verify = _verify_default()
    if verify:
        from .verify import verify_plan
        donating = (mode == "compiled"
                    and bool(getattr(be, "supports_donation", False))
                    and bool(getattr(be, "donate", False)))
        verify_plan(p, donate=donating,
                    kernel_variants=kernel_variants or None,
                    collect_lints=False).raise_if_failed()
    program = p.program
    env: Dict[str, _Slot] = {}
    stats = ExecStats()
    bound = dict(program.inputs)
    if inputs:
        bound.update(inputs)
    for k, v in bound.items():
        if type(v).__name__ == "ShapeDtypeStruct":
            raise PlanExecutionError(
                f"program input {k!r} is abstract; pass a concrete array")
        env[k] = _Slot(host=np.asarray(v), valid_host=True)

    if mode == "compiled":
        from .compile import compile_plan
        cache = p.meta.setdefault("_compiled", {})
        key = be.name if fuse_loops else be.name + ":nofuse"
        if kernel_variants:
            key += f"|kv={_kv_key(kernel_variants)}"
        # placement twins share be.name ("mesh"); without a placement
        # discriminator, alternating placements would thrash the
        # identity check below into recompiling every call
        pk = getattr(be, "placement_key", None)
        if pk:
            key += f"|mesh={pk!r}"
        fingerprint = hash(tuple(p.ops))   # ops may be mutated by callers
        compiled, fp = cache.get(key, (None, None))
        if compiled is None or compiled.backend is not be \
                or fp != fingerprint:
            tc = time.perf_counter()
            compiled = compile_plan(p, be, fuse_loops=fuse_loops,
                                    kernel_variants=kernel_variants)
            stats.compile_time = time.perf_counter() - tc
            cache[key] = (compiled, fingerprint)
        t0 = time.perf_counter()
        compiled.run(env, stats, check)
    else:
        # _nest runs per call (unlike the cached compiled lowering), so
        # it stays inside wall_time: it IS part of interpreted dispatch
        t0 = time.perf_counter()
        tree = _nest(p.ops, program)
        _run(tree, p, env, stats, check, be, kernel_variants)
    stats.wall_time = time.perf_counter() - t0

    outs = {}
    for name in (program.outputs or ()):
        slot = env.get(name)
        if slot is None:
            raise PlanExecutionError(f"output {name!r} never produced")
        if not slot.valid_host:
            if check:
                raise PlanExecutionError(
                    f"output {name!r} not on host at program end "
                    "(missing delegatestore)")
            slot.host = be.download(slot.device)
            slot.valid_host = True
        outs[name] = slot.host
    return outs, stats


def _nest(ops: List[PlanOp], program: Program):
    """linear ops -> list of ('op', PlanOp) | ('loop', loop_id, body)."""
    def parse(i: int, stop_loop: Optional[int]):
        body = []
        while i < len(ops):
            op = ops[i]
            if op.kind == "loop_begin":
                inner, i = parse(i + 1, op.loop_id)
                body.append(("loop", op.loop_id, inner))
            elif op.kind == "loop_end":
                if op.loop_id != stop_loop:
                    raise PlanExecutionError("malformed loop nesting")
                return body, i
            else:
                body.append(("op", op))
            i += 1
        return body, i
    tree, _ = parse(0, None)
    return tree


def _run(tree, p: Plan, env: Dict[str, _Slot], stats: ExecStats,
         check: bool, be: Backend, variants=None) -> None:
    program = p.program
    for item in tree:
        if item[0] == "loop":
            _, loop_id, body = item
            for _ in range(program.loops[loop_id].n_iters):
                _run(body, p, env, stats, check, be, variants)
            continue
        op: PlanOp = item[1]
        if op.kind == "directive":
            run_directive(op.directive, env, stats, check, be, p)
        elif op.kind == "block":
            _run_block(program, op.block_idx, env, stats, check, be,
                       variants)


# -- directive primitives (shared with the compiled driver) -----------------

def do_load(d: AdvancedLoad, env, stats: ExecStats, be: Backend) -> Any:
    slot = env.setdefault(d.var, _Slot())
    if not slot.valid_host:
        raise PlanExecutionError(
            f"advancedload {d.var!r}: no valid host copy")
    t = time.perf_counter()
    slot.device = be.upload(slot.host, stream=d.stream, name=d.var)
    stats.h2d_time += time.perf_counter() - t
    stats.h2d_transfers += 1
    stats.h2d_bytes += _nbytes(slot.host)
    slot.valid_device = True
    return slot.device


def do_store(d: DelegateStore, env, stats: ExecStats, be: Backend,
             handle: Any = None) -> None:
    """Download; ``handle`` overrides the slot's device value (the compiled
    driver passes the value captured at the store's program point)."""
    slot = env.setdefault(d.var, _Slot())
    if handle is None:
        if not slot.valid_device:
            raise PlanExecutionError(
                f"delegatestore {d.var!r}: no valid device copy")
        handle = slot.device
    t = time.perf_counter()
    slot.host = be.download(handle, stream=d.stream)
    stats.d2h_time += time.perf_counter() - t
    stats.d2h_transfers += 1
    stats.d2h_bytes += _nbytes(slot.host)
    slot.valid_host = True


def do_sync(d: Synchronize, stats: ExecStats, be: Backend) -> None:
    t = time.perf_counter()
    be.sync(d.stream)     # the transfer queue this callsite's group uses
    be.sync(0)            # and the compute stream the callsite ran on
    stats.sync_time += time.perf_counter() - t
    stats.syncs += 1


def group_vars(p: Plan, group: int) -> Set[str]:
    """Variables owned by ``group``: its ``mapbyname`` declaration plus
    everything its member codelets read or write (HMPP: the buffers a
    ``release`` of that group frees)."""
    names: Set[str] = set()
    for d in p.directives(GroupDecl):
        if d.group == group:
            names.update(d.mapbyname)
    for bi in p.groups.get(group, ()):
        blk = p.program.blocks[bi]
        names.update(blk.reads)
        names.update(blk.writes)
    return names


def do_release(d: Optional[Release], env, be: Backend,
               p: Optional[Plan] = None) -> None:
    """Free device buffers for ``d``'s group only (HMPP ``release`` is
    per-group).  Without a directive/plan (hand-driven callers) every
    group's buffers are freed — the pre-group legacy behaviour."""
    if d is not None and p is not None:
        names = group_vars(p, d.group)
        slots = [env[v] for v in names if v in env]
    else:
        slots = list(env.values())
    for slot in slots:
        if slot.valid_host:
            if slot.device is not None:
                be.free(slot.device)
            slot.device = None
            slot.valid_device = False


def run_directive(d, env, stats: ExecStats, check: bool,
                  be: Backend, p: Optional[Plan] = None) -> None:
    if isinstance(d, AdvancedLoad):
        do_load(d, env, stats, be)
    elif isinstance(d, DelegateStore):
        do_store(d, env, stats, be)
    elif isinstance(d, Synchronize):
        do_sync(d, stats, be)
    elif isinstance(d, Release):
        do_release(d, env, be, p)
    elif isinstance(d, (GroupDecl, Callsite)):
        pass  # metadata; the following block op performs the call


def dummy_arg(slot: _Slot, be: Backend):
    """Placeholder for a declared-but-unread input (pruned by the analyzer);
    it is provably dead inside the block, so a zeros array of the right
    shape/dtype is passed without charging a transfer."""
    src = slot.device if slot.device is not None else slot.host
    return be.alloc(np.shape(src), src.dtype)


def _run_block(program: Program, idx: int, env: Dict[str, _Slot],
               stats: ExecStats, check: bool, be: Backend,
               variants=None) -> None:
    blk = program.blocks[idx]
    actual = set(blk.effective_reads())
    if blk.kind is BlockKind.OFFLOAD:
        args = []
        for v in blk.reads:
            slot = env.setdefault(v, _Slot())
            if v not in actual:
                args.append(dummy_arg(slot, be))
                continue
            if not slot.valid_device:
                if check:
                    raise PlanExecutionError(
                        f"codelet {blk.name!r} reads {v!r}: not on device "
                        "(missing advancedload)")
                slot.device = be.upload(slot.host, name=v)
                slot.valid_device = True
            args.append(slot.device)
        t = time.perf_counter()
        outs = be.launch(kernel_fn(blk, variants), blk.reads, blk.writes,
                         args)
        stats.kernel_time += time.perf_counter() - t
        stats.kernel_calls += 1
        for w, val in zip(blk.writes, outs):
            slot = env.setdefault(w, _Slot())
            slot.device = val
            slot.valid_device, slot.valid_host = True, False
    else:
        kwargs = {}
        for v in blk.reads:
            slot = env.setdefault(v, _Slot())
            if v not in actual:
                src = slot.host if slot.host is not None else slot.device
                kwargs[v] = np.zeros(np.shape(src), src.dtype)
                continue
            if not slot.valid_host:
                if check:
                    raise PlanExecutionError(
                        f"host block {blk.name!r} reads {v!r}: not on host "
                        "(missing delegatestore)")
                slot.host = be.download(slot.device)
                slot.valid_host = True
            kwargs[v] = slot.host
        t = time.perf_counter()
        outs = blk.fn(np, **kwargs)
        stats.host_time += time.perf_counter() - t
        stats.host_calls += 1
        for w in blk.writes:
            slot = env.setdefault(w, _Slot())
            slot.host = np.asarray(outs[w])
            slot.valid_host, slot.valid_device = True, False


def run_host_oracle(program: Program,
                    inputs: Optional[Dict[str, np.ndarray]] = None
                    ) -> Dict[str, np.ndarray]:
    """Reference semantics: run every block on the host with numpy, loops
    executed for real, no device, no transfers.  The property tests assert
    ``execute(plan(p)) == execute(naive_plan(p)) == run_host_oracle(p)``."""
    env: Dict[str, np.ndarray] = {}
    bound = dict(program.inputs)
    if inputs:
        bound.update(inputs)
    for k, v in bound.items():
        env[k] = np.asarray(v)

    def run_span(blocks_iter, path):
        # execute blocks honoring loop trip counts via recursive grouping
        i = 0
        while i < len(blocks_iter):
            blk = blocks_iter[i]
            rel = blk.loop_path[len(path):]
            if not rel:
                out = blk.fn(np, **{v: env[v] for v in blk.reads})
                for w in blk.writes:
                    env[w] = np.asarray(out[w])
                i += 1
            else:
                lid = rel[0]
                j = i
                while j < len(blocks_iter) and \
                        len(blocks_iter[j].loop_path) > len(path) and \
                        blocks_iter[j].loop_path[len(path)] == lid:
                    j += 1
                for _ in range(program.loops[lid].n_iters):
                    run_span(blocks_iter[i:j], path + (lid,))
                i = j

    run_span(program.blocks, ())
    # same output contract as ``execute``: exactly ``program.outputs``
    # (in particular {} when no outputs are declared), never the raw env
    return {name: env[name] for name in program.outputs}
