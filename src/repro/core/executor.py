"""Plan executor: two memory spaces, instrumented transfers.

The paper's generated HMPP code runs on CPU+GPU; here "host" is numpy (or a
``pinned_host``-memory jax.Array — see ``optim/offload.py`` for that mode)
and "device" is the default JAX device space.  The executor walks a ``Plan``,
runs host blocks with numpy, offload blocks as jitted JAX functions, and
performs transfers ONLY where the plan says so — transfer counts/bytes/wall
times are recorded, which is exactly what the paper's Figs. 4-6 measure.

The executor also *verifies* the plan: reading a variable from a space with
no valid copy raises ``PlanExecutionError`` (the property tests drive random
programs through this).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ir import (AdvancedLoad, BlockKind, Callsite, DelegateStore, GroupDecl,
                 Plan, PlanOp, Program, Release, Synchronize)

__all__ = ["execute", "run_host_oracle", "ExecStats", "PlanExecutionError"]


class PlanExecutionError(RuntimeError):
    pass


@dataclasses.dataclass
class ExecStats:
    h2d_transfers: int = 0
    h2d_bytes: int = 0
    d2h_transfers: int = 0
    d2h_bytes: int = 0
    kernel_calls: int = 0
    host_calls: int = 0
    syncs: int = 0
    h2d_time: float = 0.0
    d2h_time: float = 0.0
    kernel_time: float = 0.0
    host_time: float = 0.0
    sync_time: float = 0.0
    wall_time: float = 0.0

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Slot:
    host: Optional[np.ndarray] = None
    device: Optional[jax.Array] = None
    valid_host: bool = False
    valid_device: bool = False


def _nbytes(x) -> int:
    return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize


@functools.lru_cache(maxsize=512)
def _jitted(fn, names: Tuple[str, ...], writes: Tuple[str, ...]):
    def wrapped(*arrays):
        out = fn(jnp, **dict(zip(names, arrays)))
        return tuple(out[w] for w in writes)
    return jax.jit(wrapped)


def execute(p: Plan, inputs: Optional[Dict[str, np.ndarray]] = None,
            *, check: bool = True
            ) -> Tuple[Dict[str, np.ndarray], ExecStats]:
    """Run the plan; return (program outputs on host, stats)."""
    program = p.program
    env: Dict[str, _Slot] = {}
    stats = ExecStats()
    bound = dict(program.inputs)
    if inputs:
        bound.update(inputs)
    for k, v in bound.items():
        if isinstance(v, jax.ShapeDtypeStruct):
            raise PlanExecutionError(
                f"program input {k!r} is abstract; pass a concrete array")
        env[k] = _Slot(host=np.asarray(v), valid_host=True)

    # nest the linear ops into a tree so loops can be re-entered n times
    tree = _nest(p.ops, program)
    t0 = time.perf_counter()
    _run(tree, program, env, stats, check)
    stats.wall_time = time.perf_counter() - t0

    outs = {}
    for name in (program.outputs or ()):
        slot = env.get(name)
        if slot is None:
            raise PlanExecutionError(f"output {name!r} never produced")
        if not slot.valid_host:
            if check:
                raise PlanExecutionError(
                    f"output {name!r} not on host at program end "
                    f"(missing delegatestore)")
            slot.host = np.asarray(slot.device)
            slot.valid_host = True
        outs[name] = slot.host
    return outs, stats


def _nest(ops: List[PlanOp], program: Program):
    """linear ops -> list of ('op', PlanOp) | ('loop', loop_id, body)."""
    def parse(i: int, stop_loop: Optional[int]):
        body = []
        while i < len(ops):
            op = ops[i]
            if op.kind == "loop_begin":
                inner, i = parse(i + 1, op.loop_id)
                body.append(("loop", op.loop_id, inner))
            elif op.kind == "loop_end":
                if op.loop_id != stop_loop:
                    raise PlanExecutionError("malformed loop nesting")
                return body, i
            else:
                body.append(("op", op))
            i += 1
        return body, i
    tree, _ = parse(0, None)
    return tree


def _run(tree, program: Program, env: Dict[str, _Slot], stats: ExecStats,
         check: bool) -> None:
    for item in tree:
        if item[0] == "loop":
            _, loop_id, body = item
            for _ in range(program.loops[loop_id].n_iters):
                _run(body, program, env, stats, check)
            continue
        op: PlanOp = item[1]
        if op.kind == "directive":
            _run_directive(op.directive, env, stats, check)
        elif op.kind == "block":
            _run_block(program, op.block_idx, env, stats, check)


def _run_directive(d, env, stats: ExecStats, check: bool) -> None:
    if isinstance(d, AdvancedLoad):
        slot = env.setdefault(d.var, _Slot())
        if not slot.valid_host:
            raise PlanExecutionError(
                f"advancedload {d.var!r}: no valid host copy")
        t = time.perf_counter()
        slot.device = jnp.asarray(slot.host)
        stats.h2d_time += time.perf_counter() - t
        stats.h2d_transfers += 1
        stats.h2d_bytes += _nbytes(slot.host)
        slot.valid_device = True
    elif isinstance(d, DelegateStore):
        slot = env.setdefault(d.var, _Slot())
        if not slot.valid_device:
            raise PlanExecutionError(
                f"delegatestore {d.var!r}: no valid device copy")
        t = time.perf_counter()
        slot.host = np.asarray(slot.device)
        stats.d2h_time += time.perf_counter() - t
        stats.d2h_transfers += 1
        stats.d2h_bytes += _nbytes(slot.host)
        slot.valid_host = True
    elif isinstance(d, Synchronize):
        t = time.perf_counter()
        for slot in env.values():
            if slot.valid_device and slot.device is not None:
                slot.device.block_until_ready()
        stats.sync_time += time.perf_counter() - t
        stats.syncs += 1
    elif isinstance(d, Release):
        for slot in env.values():
            if slot.valid_host:
                slot.device = None
                slot.valid_device = False
    elif isinstance(d, (GroupDecl, Callsite)):
        pass  # metadata; the following block op performs the call


def _dummy_like(slot: _Slot, xp):
    """Placeholder for a declared-but-unread input (pruned by the analyzer);
    it is provably dead inside the block, so a zeros array of the right
    shape/dtype is passed without charging a transfer."""
    src = slot.device if slot.device is not None else slot.host
    return xp.zeros(src.shape, src.dtype)


def _run_block(program: Program, idx: int, env: Dict[str, _Slot],
               stats: ExecStats, check: bool) -> None:
    blk = program.blocks[idx]
    actual = set(blk.effective_reads())
    if blk.kind is BlockKind.OFFLOAD:
        args = []
        for v in blk.reads:
            slot = env.setdefault(v, _Slot())
            if v not in actual:
                args.append(_dummy_like(slot, jnp))
                continue
            if not slot.valid_device:
                if check:
                    raise PlanExecutionError(
                        f"codelet {blk.name!r} reads {v!r}: not on device "
                        f"(missing advancedload)")
                slot.device = jnp.asarray(slot.host)
                slot.valid_device = True
            args.append(slot.device)
        fn = _jitted(blk.fn, tuple(blk.reads), tuple(blk.writes))
        t = time.perf_counter()
        outs = fn(*args)
        stats.kernel_time += time.perf_counter() - t
        stats.kernel_calls += 1
        for w, val in zip(blk.writes, outs):
            slot = env.setdefault(w, _Slot())
            slot.device = val
            slot.valid_device, slot.valid_host = True, False
    else:
        kwargs = {}
        for v in blk.reads:
            slot = env.setdefault(v, _Slot())
            if v not in actual:
                kwargs[v] = _dummy_like(slot, np)
                continue
            if not slot.valid_host:
                if check:
                    raise PlanExecutionError(
                        f"host block {blk.name!r} reads {v!r}: not on host "
                        f"(missing delegatestore)")
                slot.host = np.asarray(slot.device)
                slot.valid_host = True
            kwargs[v] = slot.host
        t = time.perf_counter()
        outs = blk.fn(np, **kwargs)
        stats.host_time += time.perf_counter() - t
        stats.host_calls += 1
        for w in blk.writes:
            slot = env.setdefault(w, _Slot())
            slot.host = np.asarray(outs[w])
            slot.valid_host, slot.valid_device = True, False


def run_host_oracle(program: Program,
                    inputs: Optional[Dict[str, np.ndarray]] = None
                    ) -> Dict[str, np.ndarray]:
    """Reference semantics: run every block on the host with numpy, loops
    executed for real, no device, no transfers.  The property tests assert
    ``execute(plan(p)) == execute(naive_plan(p)) == run_host_oracle(p)``."""
    env: Dict[str, np.ndarray] = {}
    bound = dict(program.inputs)
    if inputs:
        bound.update(inputs)
    for k, v in bound.items():
        env[k] = np.asarray(v)

    def run_span(blocks_iter, path):
        # execute blocks honoring loop trip counts via recursive grouping
        i = 0
        while i < len(blocks_iter):
            blk = blocks_iter[i]
            rel = blk.loop_path[len(path):]
            if not rel:
                out = blk.fn(np, **{v: env[v] for v in blk.reads})
                for w in blk.writes:
                    env[w] = np.asarray(out[w])
                i += 1
            else:
                lid = rel[0]
                j = i
                while j < len(blocks_iter) and \
                        len(blocks_iter[j].loop_path) > len(path) and \
                        blocks_iter[j].loop_path[len(path)] == lid:
                    j += 1
                for _ in range(program.loops[lid].n_iters):
                    run_span(blocks_iter[i:j], path + (lid,))
                i = j

    run_span(program.blocks, ())
    return {name: env[name] for name in (program.outputs or env.keys())}
