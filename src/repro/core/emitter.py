"""Render a Plan as HMPP-style annotated pseudo-source (paper Table 2).

This is the S2S "generated code" artifact: the program's blocks interleaved
with the planner's directives, in HMPP's pragma syntax (with TPU as the
target).  ``emit(plan)`` returns the text; the 3MM example reproduces the
structure of the paper's Table 2 (group + mapbyname up front, codelet decls,
advancedload hoisted next to the producing loop, async callsites,
synchronize before first use, delegatestore ALAP, release at the end).
"""
from __future__ import annotations

from typing import List

from .ir import (AdvancedLoad, Callsite, DelegateStore, GroupDecl, Plan,
                 Release, Synchronize)

__all__ = ["emit"]


def _fmt_args(pairs) -> str:
    by_io = {}
    for var, io in pairs:
        by_io.setdefault(io, []).append(var)
    parts = []
    for io in ("in", "out", "inout"):
        if io in by_io:
            parts.append(f"args[{', '.join(by_io[io])}].io={io}")
    return ", ".join(parts)


def emit(plan: Plan) -> str:
    prog = plan.program
    lines: List[str] = []
    indent = 0

    def w(s: str) -> None:
        lines.append("    " * indent + s)

    # plan-space autotuner verdict (ISSUE 4): which candidate this source
    # is, and what the cost model predicted/measured for it
    tuning = plan.meta.get("tuning")
    if tuning:
        cands = [c for c in tuning["candidates"] if c.get("valid")]
        chosen = next((c for c in cands
                       if c["label"] == tuning["chosen"]), None)
        w(f"#pragma omp2hmpp tuned, variant={tuning['chosen']}, "
          f"explored={len(cands)} candidates, "
          f"backend={tuning['backend']}")
        if chosen is not None:
            meas = ("" if chosen.get("measured_s") is None else
                    f", measured={chosen['measured_s'] * 1e3:.3f}ms")
            w("#pragma omp2hmpp cost, "
              f"predicted={chosen['predicted_s'] * 1e3:.3f}ms"
              f" (transfer={chosen['transfer_s'] * 1e3:.3f}"
              f" + dispatch={chosen['dispatch_s'] * 1e3:.3f}"
              f" + kernel={chosen['kernel_s'] * 1e3:.3f}){meas}")
        w("")

    # static-verifier verdict (ISSUE 7): this source was vetted for
    # races, transfer consistency and donation safety before emission
    verdict = plan.meta.get("verify")
    if verdict:
        w(f"#pragma omp2hmpp verified, ok={str(verdict['ok']).lower()}, "
          f"errors={verdict['n_errors']}, lints={verdict['n_lints']}, "
          f"ops={verdict['checked_ops']}")
        w("")

    # codelet declarations (outlined kernels), paper Table 2 lines 1-27
    for blk in prog.offload_blocks():
        g = None
        for d in plan.directives(Callsite):
            if d.block_idx == blk.idx:
                g = d.group
                break
        io = plan.io_table[blk.idx]
        w(f"#pragma hmpp <group{g}> {blk.label} codelet, "
          f"{_fmt_args(sorted((v, d.value) for v, d in io.items()))}")
        ins = ", ".join(blk.effective_reads())
        w(f"void {blk.label}({ins})  /* outlined from block "
          f"{blk.idx}: {blk.name} */")
        w("")

    w(f"int main()  /* program: {prog.name} */")
    w("{")
    indent += 1

    fused_loops = set(plan.pure_device_loops())
    for op in plan.ops:
        if op.kind == "loop_begin":
            info = prog.loops[op.loop_id]
            if op.loop_id in fused_loops:
                # planner intent: the compiled path re-verifies the body
                # structurally before actually fusing (see core.compile)
                w("#pragma hmpp region, target=TPU  /* whole-loop "
                  f"lowering: planner proved the {info.n_iters}-iteration "
                  "body device-pure; eligible for ONE fused launch */")
            w(f"for (int it{op.loop_id} = 0; it{op.loop_id} < "
              f"{info.n_iters}; ++it{op.loop_id}) {{")
            indent += 1
        elif op.kind == "loop_end":
            indent -= 1
            w("}")
        elif op.kind == "block":
            blk = prog.blocks[op.block_idx]
            if blk.kind.value == "host":
                w(f"{', '.join(blk.writes)} = {blk.name}"
                  f"({', '.join(blk.effective_reads())});   /* host */")
        elif op.kind == "directive":
            d = op.directive
            if isinstance(d, GroupDecl):
                w(f"#pragma hmpp <group{d.group}> group, target={d.target}")
                if d.mapbyname:
                    w(f"#pragma hmpp <group{d.group}> mapbyname, "
                      f"{', '.join(d.mapbyname)}")
            elif isinstance(d, AdvancedLoad):
                note = ""
                if d.hoisted_from:
                    note = ("  /* hoisted out of loop(s) "
                            f"{list(d.hoisted_from)} — ASAP after last "
                            "CPU write */")
                w(f"#pragma hmpp <group{d.group}> advancedload, "
                  f"args[{d.var}]"
                  + (", asynchronous" if d.asynchronous else "")
                  + (f", stream={d.stream}" if d.stream else "") + note)
            elif isinstance(d, DelegateStore):
                note = ""
                if d.hoisted_from:
                    note = ("  /* sunk before loop(s) "
                            f"{list(d.hoisted_from)} — ALAP before first "
                            "CPU read */")
                w(f"#pragma hmpp <group{d.group}> delegatedstore, "
                  f"args[{d.var}]"
                  + (f", stream={d.stream}" if d.stream else "") + note)
            elif isinstance(d, Callsite):
                blk = prog.blocks[d.block_idx]
                extra = ""
                if d.noupdate:
                    extra = (", args[" + ", ".join(d.noupdate)
                             + "].noupdate=true")
                if d.asynchronous:
                    extra += ", asynchronous"
                w(f"#pragma hmpp <group{d.group}> {blk.label} callsite"
                  f"{extra}")
                w(f"{blk.label}({', '.join(blk.effective_reads())});")
            elif isinstance(d, Synchronize):
                blk = prog.blocks[d.block_idx] if d.block_idx >= 0 else None
                lbl = blk.label if blk else "<emergency>"
                w(f"#pragma hmpp <group{d.group}> {lbl} synchronize"
                  + (f", stream={d.stream}" if d.stream else ""))
            elif isinstance(d, Release):
                w(f"#pragma hmpp <group{d.group}> release")

    w("return 0;")
    indent -= 1
    w("}")
    return "\n".join(lines)
