"""Transfer-directive placement — the paper's §2 optimization.

Given a ``Program``, produce a ``Plan`` through the composable pass
pipeline in ``repro.core.passes`` (linearize → placement policy →
simulate-and-fix → noupdate → stream assignment → group head/tail →
purity marking).  The monolithic planner of PRs 0-2 survives as the
individual passes; this module is the thin policy-selection entry point:

``plan(program)`` / ``plan(program, policy="optimized")``
    The paper's optimized placement: ``AdvancedLoad`` hoisted ASAP
    (Figs. 2/4b), ``DelegateStore`` sunk ALAP (Figs. 3/5b), ``noupdate``
    elision for device-resident values (Table 2), async callsites with
    ``Synchronize`` before first host use, per-component groups.

``plan(program, optimize=False)`` / ``policy="naive"``
    The paper's baseline (Figs. 4a/5a): every transfer at the callsite,
    synchronous, no residency reuse.

``plan(program, policy="grouped")``
    Optimized placement with every codelet in ONE directive group.

``plan(program, policy="auto", backend=...)``
    The plan-space explorer (``repro.core.tuner``): enumerate candidate
    plans across placement/stream/fusion/donation axes, rank them with
    the roofline-backed cost model, measure, and return the winner with
    the full ranked table in ``plan.meta["tuning"]``.

Correctness of every policy is enforced by the shared
``SimulateFixPass`` (see ``repro.core.passes.simulate``).
"""
from __future__ import annotations

from typing import Dict, Optional

from .analysis import ProgramAnalysis
from .ir import (AdvancedLoad, Callsite, DelegateStore, Plan, Program,
                 Synchronize)
from .passes import Pipeline

__all__ = ["plan", "naive_plan", "transfer_summary"]


def plan(program: Program, *, optimize: bool = True,
         policy: Optional[str] = None,
         analysis: Optional[ProgramAnalysis] = None,
         n_streams: Optional[int] = None, backend=None,
         verify: bool = True, **tune_kwargs) -> Plan:
    """Plan ``program`` under a placement policy (see module docstring).

    ``optimize`` is the legacy switch (True → "optimized", False →
    "naive"); ``policy`` overrides it.  ``backend`` and ``tune_kwargs``
    are only legal with ``policy="auto"`` (see ``repro.core.tuner.tune``
    for the knobs: axes, ``top_k``, ``reps``, ``measure``,
    ``objective="time"|"energy"|"memory"`` or a weight mapping — which
    Pareto axis the winner minimizes — plus the persistence knobs
    ``cache``/``refresh``/``calibrate``/``use_calibration`` — a repeated
    auto call answers from the persistent tuning cache without
    re-measuring, re-selecting when the objective changed); an explicit
    ``n_streams`` pins the auto policy's stream axis to that value.

    Every returned plan is vetted by the static verifier
    (``repro.core.verify``): a plan with race / transfer-consistency /
    donation-safety errors raises ``PlanVerificationError`` instead of
    being returned, and the verdict is recorded in ``meta["verify"]``.
    ``verify=False`` skips the check (the tuner verifies its candidates
    itself; hand-driven pipelines can opt out).
    """
    if policy is None:
        policy = "optimized" if optimize else "naive"
    if policy == "auto":
        from .tuner import tune
        if n_streams is not None:
            tune_kwargs.setdefault("streams", (n_streams,))
        return tune(program, backend=backend, analysis=analysis,
                    **tune_kwargs)
    if tune_kwargs or backend is not None:
        extra = sorted(tune_kwargs) + (["backend"]
                                       if backend is not None else [])
        raise TypeError(
            f"plan() got tuner-only keyword arguments {extra} with "
            f"policy={policy!r}; they are only valid with policy='auto'")
    pl = Pipeline.default(policy, n_streams=2 if n_streams is None
                          else n_streams).run(program, analysis=analysis)
    pl.meta["optimize"] = policy != "naive"
    if verify:
        from .verify import verify_plan
        shapes = analysis.shapes if analysis is not None else None
        report = verify_plan(pl, shapes=shapes)
        pl.meta["verify"] = report.meta_record()
        report.raise_if_failed()
    return pl


def naive_plan(program: Program,
               analysis: Optional[ProgramAnalysis] = None) -> Plan:
    return plan(program, policy="naive", analysis=analysis)


def transfer_summary(p: Plan) -> Dict[str, int]:
    return {
        "loads": p.count(AdvancedLoad),
        "stores": p.count(DelegateStore),
        "syncs": p.count(Synchronize),
        "callsites": p.count(Callsite),
        "noupdate_args": sum(
            len(d.noupdate) for d in p.directives(Callsite)),
    }
