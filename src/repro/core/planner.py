"""Transfer-directive placement — the paper's §2 optimization.

Given a ``Program`` and its ``ProgramAnalysis``, produce a ``Plan``:

* ``AdvancedLoad`` for every codelet input, hoisted **as early as possible**
  (right after the last host write, lifted out of loop nests to the deepest
  block shared with the callsite — Figs. 2, 4b),
* ``DelegateStore`` for every codelet output with a downstream host read,
  sunk **as late as possible** (right before the first host read, lifted to
  just before the reader's unshared loop nest — Figs. 3, 5b),
* ``noupdate`` elision for device-resident values (Table 2),
* async ``Callsite`` + ``Synchronize`` placed before the first dependent
  host use,
* one ``GroupDecl`` (+ ``mapbyname``) per connected component of codelets
  sharing data, and a final ``Release``.

``plan(program, optimize=False)`` is the paper's *baseline* policy
(Figs. 4a/5a): load every input at the callsite, store every output right
after it, synchronous, no residency reuse.

Correctness is enforced by an abstract-interpretation pass
(``_simulate_and_fix``): it walks the plan (loop bodies twice, to fixed
point), tracking per-variable host/device validity, drops loads that are
redundant on *every* execution (these become ``noupdate`` args), and inserts
emergency transfers if a placement gap is found (which the property tests
then flag, since an optimal plan should never need them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .analysis import ProgramAnalysis, analyze, common_prefix
from .ir import (AdvancedLoad, Block, BlockKind, Callsite, DelegateStore,
                 GroupDecl, Plan, PlanOp, Program, Release, Synchronize,
                 VarIO)

__all__ = ["plan", "naive_plan", "transfer_summary"]


# --------------------------------------------------------------------------
# Skeleton: linearized program with loop markers.
# --------------------------------------------------------------------------

def _linearize(program: Program) -> List[PlanOp]:
    ops: List[PlanOp] = []
    open_path: Tuple[int, ...] = ()
    for blk in program.blocks:
        path = blk.loop_path
        keep = common_prefix(open_path, path)
        for lid in reversed(open_path[len(keep):]):
            ops.append(PlanOp(kind="loop_end", loop_id=lid))
        for lid in path[len(keep):]:
            ops.append(PlanOp(kind="loop_begin", loop_id=lid))
        open_path = path
        ops.append(PlanOp(kind="block", block_idx=blk.idx))
    for lid in reversed(open_path):
        ops.append(PlanOp(kind="loop_end", loop_id=lid))
    return ops


def _pos_of_block(ops: List[PlanOp], idx: int) -> int:
    for i, op in enumerate(ops):
        if op.kind == "block" and op.block_idx == idx:
            return i
    raise KeyError(idx)


def _depth_at(ops: List[PlanOp], pos: int) -> Tuple[int, ...]:
    path: List[int] = []
    for op in ops[:pos]:
        if op.kind == "loop_begin":
            path.append(op.loop_id)
        elif op.kind == "loop_end":
            path.pop()
    return tuple(path)


def _after_hoisted(ops: List[PlanOp], blk_pos: int,
                   target_path: Tuple[int, ...]) -> int:
    """Insertion index just after ``blk_pos`` once all loops deeper than
    ``target_path`` have closed (ASAP placement, Fig. 2)."""
    path = list(_depth_at(ops, blk_pos))
    i = blk_pos + 1
    while tuple(path) != tuple(target_path) and i < len(ops):
        op = ops[i]
        if op.kind == "loop_begin":
            path.append(op.loop_id)
        elif op.kind == "loop_end":
            path.pop()
        i += 1
    return i


def _before_hoisted(ops: List[PlanOp], blk_pos: int,
                    target_path: Tuple[int, ...]) -> int:
    """Insertion index just before ``blk_pos``, lifted before any loop_begin
    opening loops deeper than ``target_path`` (ALAP placement, Fig. 3)."""
    path = list(_depth_at(ops, blk_pos))
    i = blk_pos
    while tuple(path) != tuple(target_path) and i > 0:
        op = ops[i - 1]
        if op.kind == "loop_begin":
            path.pop()
        elif op.kind == "loop_end":
            path.append(op.loop_id)
        i -= 1
    return i


# --------------------------------------------------------------------------
# Placement computation.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Insertion:
    pos: int           # index into skeleton ops; inserted before ops[pos]
    order: int         # tie-break: stable order of creation
    op: PlanOp


def _place_optimized(an: ProgramAnalysis, ops: List[PlanOp]
                     ) -> List[_Insertion]:
    program = an.program
    ins: List[_Insertion] = []
    order = [0]

    def add(pos: int, directive) -> None:
        ins.append(_Insertion(pos, order[0], PlanOp("directive",
                                                    directive=directive)))
        order[0] += 1

    seen_loads: Set[Tuple[str, int]] = set()       # (var, pos) dedupe
    seen_stores: Set[Tuple[str, int]] = set()

    def straight_load(var, g, blk, lw):
        """ASAP load covering the straight-line (iteration-1) path."""
        if lw is None:
            pos, hoisted = 0, ()
        else:
            target = common_prefix(lw.loop_path, blk.loop_path)
            writer_pos = _pos_of_block(ops, lw.block_idx)
            pos = _after_hoisted(ops, writer_pos, target)
            hoisted = lw.loop_path[len(target):]
        if (var, pos) not in seen_loads:
            seen_loads.add((var, pos))
            add(pos, AdvancedLoad(var=var, group=g, asynchronous=True,
                                  hoisted_from=hoisted))

    for blk in program.offload_blocks():
        g = an.group_of[blk.idx]
        blk_pos = _pos_of_block(ops, blk.idx)

        # ---- inputs: AdvancedLoad, hoisted ASAP (Fig. 2 / 4b) ------------
        # The dynamic last write at the callsite is lw (straight-line,
        # iteration 1) and — when the callsite sits in a loop whose body
        # also writes the var AFTER it — lwc (loop-carried, iterations ≥ 2).
        for var, io in sorted(an.io_table[blk.idx].items()):
            if io is VarIO.OUT:
                continue  # never read by the codelet: no upload (paper: E)
            lw = an.last_write_before(var, blk.idx)
            lwc = an.last_carried_write(var, blk)
            straight_resident = (lw is not None
                                 and lw.kind is BlockKind.OFFLOAD)
            if lwc is None:
                if straight_resident:
                    continue          # noupdate (tagged later)
                straight_load(var, g, blk, lw)
            elif lwc.kind is BlockKind.OFFLOAD:
                # iterations ≥ 2 are device-resident; cover iteration 1
                if not straight_resident:
                    straight_load(var, g, blk, lw)
            else:
                # carried HOST write: iterations ≥ 2 need a fresh upload
                if straight_resident:
                    # iter 1 resident → ASAP after the carried writer
                    # (end of body i covers body i+1's read)
                    target = common_prefix(lwc.loop_path, blk.loop_path)
                    wpos = _pos_of_block(ops, lwc.block_idx)
                    pos = _after_hoisted(ops, wpos, target)
                    hoisted = lwc.loop_path[len(target):]
                else:
                    # host-fresh on every path → one load just before the
                    # callsite (count-optimal; matches naive's count here)
                    pos, hoisted = blk_pos, ()
                if (var, pos) not in seen_loads:
                    seen_loads.add((var, pos))
                    add(pos, AdvancedLoad(var=var, group=g,
                                          asynchronous=True,
                                          hoisted_from=hoisted))

        # ---- outputs: DelegateStore, sunk ALAP (Fig. 3 / 5b) -------------
        for var, io in sorted(an.io_table[blk.idx].items()):
            if io is VarIO.IN:
                continue
            carried_r = an.carried_host_read(var, blk)
            if carried_r is not None:
                # a host block EARLIER in the shared loop reads next
                # iteration's value → store right after the callsite
                pos = blk_pos + 1
                if (var, pos) not in seen_stores:
                    seen_stores.add((var, pos))
                    add(pos, Synchronize(block_idx=blk.idx, group=g))
                    add(pos, DelegateStore(var=var, group=g))
            reader = an.first_host_read_after(var, blk.idx)
            if reader is None:
                if var in getattr(program, "outputs", ()):  # virtual end read
                    killed = any(
                        ev.is_write and ev.block_idx > blk.idx
                        for ev in an.events.get(var, ()))
                    if killed:
                        continue
                    pos = len(ops)
                    add(pos, Synchronize(block_idx=blk.idx, group=g))
                    add(pos, DelegateStore(var=var, group=g))
                continue  # dead on host: no download (paper: A)
            target = common_prefix(blk.loop_path, reader.loop_path)
            reader_pos = _pos_of_block(ops, reader.block_idx)
            pos = _before_hoisted(ops, reader_pos, target)
            if (var, pos) in seen_stores:
                continue
            seen_stores.add((var, pos))
            hoisted = reader.loop_path[len(target):]
            # synchronize the async callsite just before its first host use
            add(pos, Synchronize(block_idx=blk.idx, group=g))
            add(pos, DelegateStore(var=var, group=g, hoisted_from=hoisted))

    return ins


def _place_naive(an: ProgramAnalysis, ops: List[PlanOp]) -> List[_Insertion]:
    """Paper Figs. 4a/5a: all transfers at the callsite, synchronous."""
    ins: List[_Insertion] = []
    order = [0]

    def add(pos, directive):
        ins.append(_Insertion(pos, order[0], PlanOp("directive",
                                                    directive=directive)))
        order[0] += 1

    for blk in an.program.offload_blocks():
        g = an.group_of[blk.idx]
        pos = _pos_of_block(ops, blk.idx)
        for var, io in sorted(an.io_table[blk.idx].items()):
            if io is not VarIO.OUT:
                add(pos, AdvancedLoad(var=var, group=g, asynchronous=False))
        outs = [var for var, io in sorted(an.io_table[blk.idx].items())
                if io is not VarIO.IN]
        if outs:
            # one wait point per callsite (Fig. 5a), then every download —
            # not a sync per output
            add(pos + 1, Synchronize(block_idx=blk.idx, group=g))
            for var in outs:
                add(pos + 1, DelegateStore(var=var, group=g))
    return ins


def _merge(ops: List[PlanOp], ins: List[_Insertion]) -> List[PlanOp]:
    out: List[PlanOp] = []
    by_pos: Dict[int, List[_Insertion]] = {}
    for i in ins:
        by_pos.setdefault(i.pos, []).append(i)
    for pos in by_pos:
        by_pos[pos].sort(key=lambda x: x.order)
    for idx in range(len(ops) + 1):
        for i in by_pos.get(idx, ()):
            out.append(i.op)
        if idx < len(ops):
            out.append(ops[idx])
    return out


# --------------------------------------------------------------------------
# Abstract interpretation: validate, elide redundant loads, tag noupdate.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _VState:
    valid_host: bool
    valid_device: bool


def _simulate(program: Program, an: ProgramAnalysis, ops: List[PlanOp],
              *, naive: bool):
    """Walk the plan; loop bodies are interpreted twice (the standard
    2-iteration trick) so cross-iteration residency is exact for programs
    whose bodies don't change behaviour after iteration 2 (ours don't:
    block read/write sets are static).

    Returns (always_redundant, gaps) where gaps is a list of
    (pos, emergency PlanOp) needed for correctness.
    """
    state: Dict[str, _VState] = {
        v: _VState(True, False) for v in program.inputs
    }
    load_hits: Dict[int, List[bool]] = {}   # op position -> redundancy flags
    store_hits: Dict[int, List[bool]] = {}
    gaps: Dict[Tuple[int, str, str], Tuple[int, PlanOp]] = {}

    # pre-index loop spans
    spans: Dict[int, Tuple[int, int]] = {}
    stack: List[Tuple[int, int]] = []
    for i, op in enumerate(ops):
        if op.kind == "loop_begin":
            stack.append((op.loop_id, i))
        elif op.kind == "loop_end":
            lid, start = stack.pop()
            spans[lid] = (start, i)

    def exec_range(lo: int, hi: int):
        i = lo
        while i < hi:
            op = ops[i]
            if op.kind == "loop_begin":
                start, end = spans[op.loop_id]
                for _ in range(2):           # 2-iteration abstraction
                    exec_range(start + 1, end)
                i = end + 1
                continue
            if op.kind == "directive":
                d = op.directive
                if isinstance(d, AdvancedLoad):
                    st = state.setdefault(d.var, _VState(False, False))
                    if not st.valid_host:
                        # a host copy is required; upstream store missing
                        raise _PlanGap(
                            f"load of {d.var!r} with no valid host copy")
                    load_hits.setdefault(i, []).append(st.valid_device)
                    st.valid_device = True
                elif isinstance(d, DelegateStore):
                    st = state.setdefault(d.var, _VState(False, False))
                    if not st.valid_device:
                        raise _PlanGap(
                            f"store of {d.var!r} with no valid device copy")
                    store_hits.setdefault(i, []).append(st.valid_host)
                    st.valid_host = True
            elif op.kind == "block":
                blk = program.blocks[op.block_idx]
                on_device = blk.kind is BlockKind.OFFLOAD
                for v in blk.effective_reads():
                    st = state.setdefault(v, _VState(False, False))
                    ok = st.valid_device if on_device else st.valid_host
                    if not ok:
                        src_ok = st.valid_host if on_device else \
                            st.valid_device
                        if not src_ok:
                            raise _PlanGap(
                                f"{blk.name!r} reads {v!r} but no valid copy "
                                f"exists anywhere")
                        fix = (AdvancedLoad(v, group=0, asynchronous=False)
                               if on_device else DelegateStore(v, group=0))
                        key = (i, v, type(fix).__name__)
                        gaps.setdefault(
                            key, (i, PlanOp("directive", directive=fix)))
                        if on_device:
                            st.valid_device = True
                        else:
                            st.valid_host = True
                for v in blk.writes:
                    st = state.setdefault(v, _VState(False, False))
                    if on_device:
                        st.valid_device, st.valid_host = True, False
                    else:
                        st.valid_host, st.valid_device = True, False
            i += 1

    exec_range(0, len(ops))
    always_redundant = {
        pos for pos, flags in load_hits.items() if flags and all(flags)
    }
    always_redundant |= {
        pos for pos, flags in store_hits.items() if flags and all(flags)
    }
    return always_redundant, list(gaps.values())


class _PlanGap(Exception):
    pass


def _simulate_and_fix(program: Program, an: ProgramAnalysis,
                      ops: List[PlanOp], *, naive: bool,
                      elide: bool) -> List[PlanOp]:
    for _round in range(8):
        try:
            redundant, gaps = _simulate(program, an, ops, naive=naive)
        except _PlanGap as e:
            raise RuntimeError(f"planner produced an invalid plan: {e}")
        if gaps:
            # insert emergency transfers (kept rare by construction)
            for pos, op in sorted(gaps, key=lambda t: -t[0]):
                ops = ops[:pos] + [op] + ops[pos:]
            continue
        if elide and redundant:
            ops = [op for i, op in enumerate(ops) if i not in redundant]
            continue
        return ops
    raise RuntimeError("planner failed to converge")


def _tag_noupdate(program: Program, an: ProgramAnalysis,
                  ops: List[PlanOp]) -> List[PlanOp]:
    """Annotate each callsite with the inputs that arrive device-resident
    (i.e. no AdvancedLoad between the last producer and the callsite) —
    the paper's ``args[x].noupdate=true``."""
    loaded_since_host_write: Set[str] = set()
    out: List[PlanOp] = []
    # track which vars have a load op anywhere (vs pure residency)
    for op in ops:
        if op.kind == "block":
            blk = program.blocks[op.block_idx]
            if blk.kind is BlockKind.OFFLOAD:
                io = an.io_table[blk.idx]
                noup = tuple(
                    v for v, d in sorted(io.items())
                    if d is not VarIO.OUT and v not in
                    loaded_since_host_write
                )
                out.append(PlanOp("directive", directive=Callsite(
                    block_idx=blk.idx, group=an.group_of[blk.idx],
                    io=tuple(sorted((v, d.value) for v, d in io.items())),
                    noupdate=noup, asynchronous=True)))
                out.append(op)
                for v in blk.writes:
                    loaded_since_host_write.discard(v)
                continue
            else:
                for v in blk.writes:
                    loaded_since_host_write.discard(v)
        if op.kind == "directive" and isinstance(op.directive, AdvancedLoad):
            loaded_since_host_write.add(op.directive.var)
        out.append(op)
    return out


# --------------------------------------------------------------------------
# Stream assignment — one logical transfer stream per group.
# --------------------------------------------------------------------------

def _assign_streams(ops: List[PlanOp]) -> List[PlanOp]:
    """Give every transfer/sync directive a logical stream id derived from
    its group: stream 0 is the compute stream, groups round-robin over the
    transfer streams 1..N so a stream-aware backend double-buffers uploads
    of independent groups and ``Synchronize`` waits only its own queue."""
    def stream_of(group: int) -> int:
        return 1 + (group % 2)

    out: List[PlanOp] = []
    for op in ops:
        d = op.directive
        if op.kind == "directive" and isinstance(
                d, (AdvancedLoad, DelegateStore, Synchronize)):
            d = dataclasses.replace(d, stream=stream_of(d.group))
            op = PlanOp("directive", directive=d)
        out.append(op)
    return out


# --------------------------------------------------------------------------
# Loop-invariance marking — proof the compiler relies on for whole-loop
# lowering (lax.fori_loop over the body).
# --------------------------------------------------------------------------

def _pure_device_loops(program: Program,
                       ops: List[PlanOp]) -> Tuple[int, ...]:
    """Loop ids whose body is pure device work in THIS plan: only offload
    blocks and metadata/sync directives inside — no host blocks and no
    ``AdvancedLoad``/``DelegateStore``/``Release``.  The compiled path may
    roll such a loop whole into one fused launch, because no per-iteration
    op needs the host."""
    pure: Dict[int, bool] = {}
    stack: List[int] = []
    for op in ops:
        if op.kind == "loop_begin":
            stack.append(op.loop_id)
            pure.setdefault(op.loop_id, True)
        elif op.kind == "loop_end":
            stack.pop()
        elif stack:
            ok = True
            if op.kind == "block":
                ok = program.blocks[op.block_idx].kind is BlockKind.OFFLOAD
            elif op.kind == "directive":
                ok = not isinstance(
                    op.directive, (AdvancedLoad, DelegateStore, Release))
            if not ok:
                for lid in stack:
                    pure[lid] = False
    return tuple(sorted(lid for lid, v in pure.items() if v))


# --------------------------------------------------------------------------
# Entry points.
# --------------------------------------------------------------------------

def plan(program: Program, *, optimize: bool = True,
         analysis: Optional[ProgramAnalysis] = None) -> Plan:
    an = analysis or analyze(program)
    skeleton = _linearize(program)
    ins = (_place_optimized if optimize else _place_naive)(an, skeleton)
    ops = _merge(skeleton, ins)
    ops = _simulate_and_fix(program, an, ops, naive=not optimize,
                            elide=optimize)
    ops = _tag_noupdate(program, an, ops)
    ops = _assign_streams(ops)

    # group declarations up front, releases at the end (paper Table 2)
    head: List[PlanOp] = []
    for g, blks in sorted(an.groups.items()):
        shared: Set[str] = set()
        seen: Set[str] = set()
        for bi in blks:
            for v in set(program.blocks[bi].effective_reads()) | \
                    set(program.blocks[bi].writes):
                if v in seen:
                    shared.add(v)
                seen.add(v)
        head.append(PlanOp("directive", directive=GroupDecl(
            group=g, mapbyname=tuple(sorted(shared)), target="TPU")))
    tail = [PlanOp("directive", directive=Release(group=g))
            for g in sorted(an.groups)]

    all_ops = head + ops + tail
    return Plan(program=program, ops=all_ops,
                groups=an.groups, io_table=an.io_table,
                meta={"optimize": optimize,
                      "pure_device_loops":
                          _pure_device_loops(program, all_ops)})


def naive_plan(program: Program,
               analysis: Optional[ProgramAnalysis] = None) -> Plan:
    return plan(program, optimize=False, analysis=analysis)


def transfer_summary(p: Plan) -> Dict[str, int]:
    return {
        "loads": p.count(AdvancedLoad),
        "stores": p.count(DelegateStore),
        "syncs": p.count(Synchronize),
        "callsites": p.count(Callsite),
        "noupdate_args": sum(
            len(d.noupdate) for d in p.directives(Callsite)),
    }
