"""Program IR for the OMP2HMPP-style offload planner.

The paper's input is C source with OpenMP pragmas; ours is a ``Program``: an
ordered list of ``Block``s (host or offload), optionally nested in counted
loops, operating on a shared environment of named arrays.  This is the
JAX-native analogue of the paper's AST view of the program: enough structure
for the def/use + loop-nesting analysis of Section 2 of the paper, while the
block bodies stay ordinary (traceable) array code.

Block body convention
---------------------
Every block function has the signature ``fn(xp, **arrays) -> dict``:
``xp`` is ``numpy`` when the block runs on the host and ``jax.numpy`` when it
runs on the device (or is traced for analysis).  It must return a dict
mapping written variable names to arrays.  This single-source convention is
what lets the analyzer trace *both* host and offload blocks to jaxprs.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "BlockKind", "VarIO", "Block", "LoopInfo", "Program",
    "Directive", "AdvancedLoad", "DelegateStore", "Callsite", "Synchronize",
    "Release", "GroupDecl", "Plan", "PlanOp", "PlanExecutionError",
]


class PlanExecutionError(RuntimeError):
    """A plan could not be executed (or, for the static-verifier subclass
    ``repro.core.verify.PlanVerificationError``, was proven un-executable
    before running).  Lives here rather than in ``executor`` so the
    jax-free verifier can subclass it without importing the backend stack.
    """


class BlockKind(enum.Enum):
    HOST = "host"
    OFFLOAD = "offload"


class VarIO(enum.Enum):
    """HMPP ``args[x].io=`` classification for a variable w.r.t. a codelet."""
    IN = "in"
    OUT = "out"
    INOUT = "inout"


@dataclasses.dataclass(frozen=True)
class LoopInfo:
    loop_id: int
    n_iters: int
    parent_path: Tuple[int, ...]  # enclosing loop ids, outermost first

    @property
    def path(self) -> Tuple[int, ...]:
        return self.parent_path + (self.loop_id,)


@dataclasses.dataclass
class Block:
    idx: int
    kind: BlockKind
    fn: Callable[..., Dict[str, Any]]
    reads: Tuple[str, ...]          # declared inputs (superset of actual)
    writes: Tuple[str, ...]
    loop_path: Tuple[int, ...]      # enclosing loop ids, outermost first
    name: str
    # Filled in by analysis:
    actual_reads: Optional[Tuple[str, ...]] = None
    # Pallas kernel name (repro.kernels.variants registry) when this block
    # is a tunable kernel launch; its declared ``reads`` are then, in
    # order, the kernel's array operands.  The tuner crosses the plan grid
    # with the kernel's tile variants and the executor binds the chosen
    # tile kwargs onto ``fn`` at launch.
    kernel: Optional[str] = None

    @property
    def label(self) -> str:
        return f"_instr_{self.name}_ol_{self.idx}"

    def effective_reads(self) -> Tuple[str, ...]:
        return self.actual_reads if self.actual_reads is not None else self.reads


class Program:
    """Builder for block programs.

    >>> p = Program()
    >>> p.bind("A", np.zeros((4, 4)))
    >>> p.host(init_fn, reads=(), writes=("A",), name="init")
    >>> with p.loop(10):
    ...     p.offload(kernel_fn, reads=("A",), writes=("C",), name="k0")
    >>> p.host(use_fn, reads=("C",), writes=("out",), name="use")
    """

    def __init__(self, name: str = "main"):
        self.name = name
        self.blocks: List[Block] = []
        self.loops: Dict[int, LoopInfo] = {}
        self.inputs: Dict[str, Any] = {}      # name -> concrete array or SDS
        self.outputs: Tuple[str, ...] = ()    # vars wanted on host at exit
        self._loop_stack: List[int] = []
        self._next_loop_id = 0

    # -- builder -----------------------------------------------------------
    def bind(self, name: str, value: Any) -> None:
        """Declare a program input (concrete array or ShapeDtypeStruct)."""
        self.inputs[name] = value

    def set_outputs(self, *names: str) -> None:
        """Vars the caller wants back on the host when the program ends."""
        self.outputs = tuple(names)

    def _add_block(self, kind: BlockKind, fn, reads, writes, name,
                   kernel=None) -> Block:
        blk = Block(
            idx=len(self.blocks), kind=kind, fn=fn,
            reads=tuple(reads), writes=tuple(writes),
            loop_path=tuple(self._loop_stack),
            name=name or fn.__name__,
            kernel=kernel,
        )
        self.blocks.append(blk)
        return blk

    def host(self, fn, *, reads: Sequence[str], writes: Sequence[str],
             name: str = "") -> Block:
        return self._add_block(BlockKind.HOST, fn, reads, writes, name)

    def offload(self, fn, *, reads: Sequence[str], writes: Sequence[str],
                name: str = "", kernel: Optional[str] = None) -> Block:
        """The analogue of ``#pragma omp parallel for target cuda``.

        ``kernel`` tags the block as a tunable Pallas kernel launch (a name
        from ``repro.kernels.variants.KERNELS``); ``fn`` must then accept
        that kernel's tile parameters as keyword arguments (e.g.
        ``block_q=``/``block_k=``) and ``reads`` must list the kernel's
        array operands in the registry's order.
        """
        return self._add_block(BlockKind.OFFLOAD, fn, reads, writes, name,
                               kernel=kernel)

    def loop(self, n_iters: int) -> "_LoopCtx":
        return _LoopCtx(self, n_iters)

    # -- queries used by the analyzer/planner ------------------------------
    def loop_path_of(self, idx: int) -> Tuple[int, ...]:
        return self.blocks[idx].loop_path

    def offload_blocks(self) -> List[Block]:
        return [b for b in self.blocks if b.kind is BlockKind.OFFLOAD]

    def host_blocks(self) -> List[Block]:
        return [b for b in self.blocks if b.kind is BlockKind.HOST]


class _LoopCtx:
    def __init__(self, prog: Program, n_iters: int):
        self.prog, self.n_iters = prog, n_iters

    def __enter__(self):
        info = LoopInfo(
            loop_id=self.prog._next_loop_id,
            n_iters=self.n_iters,
            parent_path=tuple(self.prog._loop_stack),
        )
        self.prog._next_loop_id += 1
        self.prog.loops[info.loop_id] = info
        self.prog._loop_stack.append(info.loop_id)
        self.info = info
        return info

    def __exit__(self, *exc):
        self.prog._loop_stack.pop()
        return False


# ---------------------------------------------------------------------------
# Directives — the HMPP vocabulary the planner emits (paper §1.1).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Directive:
    pass


@dataclasses.dataclass(frozen=True)
class AdvancedLoad(Directive):
    """Upload ``var`` host→device.  Placed as early as possible (Fig. 4b).

    ``stream`` is the logical transfer queue the upload is enqueued on
    (assigned per group by the planner; 0 = the compute stream).  Backends
    map logical streams onto their physical ones.
    """
    var: str
    group: int
    asynchronous: bool = True
    hoisted_from: Tuple[int, ...] = ()   # loop ids it was hoisted out of
    stream: int = 0


@dataclasses.dataclass(frozen=True)
class DelegateStore(Directive):
    """Download ``var`` device→host.  Placed as late as possible (Fig. 5b)."""
    var: str
    group: int
    hoisted_from: Tuple[int, ...] = ()
    stream: int = 0


@dataclasses.dataclass(frozen=True)
class Callsite(Directive):
    block_idx: int
    group: int
    io: Tuple[Tuple[str, str], ...]        # (var, "in"/"out"/"inout")
    noupdate: Tuple[str, ...] = ()         # vars already device-resident
    asynchronous: bool = True


@dataclasses.dataclass(frozen=True)
class Synchronize(Directive):
    """Wait for async work on ``stream`` issued for callsite ``block_idx``
    (placed before first use).  With a stream-aware backend this is a real
    wait point, not a no-op."""
    block_idx: int
    group: int
    stream: int = 0


@dataclasses.dataclass(frozen=True)
class Release(Directive):
    group: int


@dataclasses.dataclass(frozen=True)
class GroupDecl(Directive):
    group: int
    mapbyname: Tuple[str, ...]
    target: str = "CUDA"  # kept for fidelity with the paper; ours is "TPU"


# ---------------------------------------------------------------------------
# Plan — the "generated source": program items interleaved with directives.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanOp:
    """One entry of the linearized plan.

    kind: 'directive' | 'block' | 'loop_begin' | 'loop_end'
    """
    kind: str
    directive: Optional[Directive] = None
    block_idx: Optional[int] = None
    loop_id: Optional[int] = None


@dataclasses.dataclass
class Plan:
    program: Program
    ops: List[PlanOp]
    groups: Dict[int, Tuple[int, ...]]       # group id -> offload block idxs
    io_table: Dict[int, Dict[str, VarIO]]    # block idx -> var -> io
    # meta keys set by the planner pass pipeline (repro.core.passes):
    #   "optimize"           — True for any non-naive policy (legacy)
    #   "policy"             — placement policy name that produced this
    #       plan ("optimized" / "naive" / "grouped" / registered ones)
    #   "n_transfer_streams" — stream count the StreamAssignPass used
    #   "pure_device_loops"  — loop ids whose body holds only offload
    #       blocks and metadata/sync directives (no host blocks, no
    #       AdvancedLoad/DelegateStore/Release).  Together with
    #       ``program.loops[lid].n_iters`` this is what the compiled path
    #       needs to roll the whole loop (or nest) into one fused launch.
    #   "var_nbytes"         — concrete byte size of every program var
    #       (the cost model's raw material)
    # and by the plan-space tuner (repro.core.tuner):
    #   "tuning"             — {"chosen", "objective", "winners",
    #       "pareto", "backend", "hw", "calibration", "predictor",
    #       "candidates"}: the ranked candidate table, each entry
    #       carrying the cost breakdown (transfer_s/dispatch_s/kernel_s/
    #       predicted_s) plus the ISSUE-10 objective columns (energy_j —
    #       modeled joules; peak_bytes — static residency-walk peak;
    #       analytic_s — default-constant predicted seconds),
    #       measured_s when its execution class was run,
    #       calibrated_s when a fit was made, predictor_s when a
    #       cross-program model priced the grid, and alias_of naming the
    #       class survivor for dominance-pruned (execution-identical)
    #       configs.  "hw" is the pricing constants actually used
    #       (calibrated when a fit was cached); "calibration" records
    #       the fit: {"n_rows", "fitted", "accepted",
    #       "rank_corr_before", "rank_corr_after"}.
    #       "objective" (inside "tuning") — what the chosen candidate
    #       minimizes: "time" | "energy" | "memory" | {objective:
    #       weight}; "winners" maps each objective to its frontier-
    #       guaranteed winner label; "pareto" is the mutually
    #       non-dominated surface of the table, fastest-first:
    #       [{"label", "time_s", "energy_j", "peak_bytes"}, ...]
    #       (time_s is measured when the run measured, predicted
    #       otherwise).
    #       "predictor" (inside "tuning") — the cross-program cold-start
    #       model's outcome for this run: {"n_rows", "n_programs",
    #       "source" ("fit" | "cache" | None), "accepted",
    #       "rank_corr_analytic", "rank_corr_predictor",
    #       "used_for_ranking"}; None when tuning ran cache-less.
    #       Accepted means the learned ranking of this program's
    #       measured survivors was no worse than the uncalibrated
    #       analytic model's (the PR-5 no-regression gate).
    #       "kernel_variants" (inside "tuning") — the winner's tile
    #       choice per kernel-tagged block:
    #       {kernel_name: {param: value}}, e.g.
    #       {"flash_attention": {"block_q": 128, "block_k": 64}};
    #       empty dict when the program has no kernel blocks
    #       "pruned_invalid" (inside "tuning") — how many candidate
    #       configs the static verifier (repro.core.verify) rejected
    #       before pricing/measuring; 0 for a healthy pipeline (the
    #       verifier prunes nothing the simulator approved)
    #   "kernel_variants"    — the same mapping hoisted to the top level
    #       so ``execute()`` (and winner_exec_kwargs) launch the winning
    #       tile sizes by default
    #   "tuning_cache"       — {"hit", "measurements", "path",
    #       "fingerprint"}: whether the persistent cache
    #       (repro.core.tunecache) answered, and how many execution
    #       classes were measured this call (0 on a hit)
    #   "fuse_loops"/"donate" — how the winning plan wants executing
    #   "mesh"               — present only when the tuner ran on a
    #       mesh-capable backend AND a sharded placement won:
    #       {"shape": [2, 4], "axes": ["data", "model"],
    #        "placement": "fsdp" | "tp" | "pipeline-registered policy",
    #        "n_devices": 8,
    #        "specs": {var: [entry, ...]},   # PartitionSpec entries per
    #            var; entry is a mesh-axis name, a list of axis names,
    #            or null (replicated dim); [] = fully replicated
    #        "dropped": [[var, axis, dim], ...]}  # divisibility-guard
    #            drops — sharding requests that stayed replicated
    #       ``execute()`` re-applies it via backend.with_placement();
    #       ``verify_plan`` validates it (kind "mesh-placement") and
    #       treats sharded operands as cross-device sync points.  The
    #       same record also sits at meta["tuning"]["mesh"] for every
    #       tuned-on-mesh plan (including replicate winners, where the
    #       top-level key is absent).
    # and by the static plan verifier (repro.core.verify):
    #   "verify"             — {"ok", "checked_ops", "n_errors",
    #       "n_lints", "counts"}: the verifier's verdict for this plan
    #       (counts maps violation kind -> occurrences; lints — e.g.
    #       the naive policy's redundant transfers — never fail a
    #       plan).  Set by plan(), tune() and cache-hit rebuilds; the
    #       full op-indexed diagnostics live on the VerifyReport the
    #       verifier returns, not in meta.
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def directives(self, cls=None) -> List[Directive]:
        out = [op.directive for op in self.ops if op.kind == "directive"]
        if cls is not None:
            out = [d for d in out if isinstance(d, cls)]
        return out

    def count(self, cls) -> int:
        return len(self.directives(cls))

    def pure_device_loops(self) -> Tuple[int, ...]:
        """Loop ids the planner proved transfer-free (fusable whole)."""
        return tuple(self.meta.get("pure_device_loops", ()))

    def predicted_cost(self) -> Optional[Dict[str, Any]]:
        """The tuner's cost record for this plan (None if not tuned)."""
        tuning = self.meta.get("tuning")
        if not tuning:
            return None
        for c in tuning["candidates"]:
            if c["label"] == tuning["chosen"]:
                return c
        return None

    def tuning_table(self) -> List[Dict[str, Any]]:
        """Ranked candidate records from the plan-space exploration
        (empty if this plan was not produced by ``policy="auto"``)."""
        tuning = self.meta.get("tuning")
        return list(tuning["candidates"]) if tuning else []

    def tuning_calibration(self) -> Optional[Dict[str, Any]]:
        """The measured-calibration record from the tuning run (None if
        not tuned, not measured, or calibration was disabled)."""
        tuning = self.meta.get("tuning")
        return tuning.get("calibration") if tuning else None

    def tuning_cache_info(self) -> Optional[Dict[str, Any]]:
        """Cache outcome of the tuning run: {"hit", "measurements",
        "path", "fingerprint"} (None if this plan was not tuned)."""
        return self.meta.get("tuning_cache")
