"""Static plan verifier — an independent checker for generated plans.

OMP2HMPP's core guarantee is that the directives it *generates* are
correct: the paper's AST analysis (§2) proves every ``advancedload`` /
``delegatedstore`` placement preserves the source program's semantics.
Our plans now come from four sources (pass pipeline, tuner candidate
enumeration, tunecache round-trips, hand-built tests) but until this
module the only validity authority was ``SimulateFixPass`` — which both
*fixes* and *judges* plans, so a planner bug, a stale cache entry or a
bad mutation would execute silently wrong.

``verify_plan(plan)`` re-derives correctness from nothing but the plan:
it walks the linearized ops (loop bodies twice — the same 2-iteration
abstraction ``simulate`` uses) against a per-variable memory-state
abstract interpretation and a happens-before model of the runtime's
streams:

    host / device        which spaces hold a valid copy of the var
    dirty                the device copy is newer than the host copy
                         (set by offload writes, cleared by stores)
    in-flight (s, g)     an asynchronous upload enqueued on logical
                         stream ``s`` for group ``g`` that no wait
                         point has completed yet
    async producer       the op index of an asynchronous callsite whose
                         write to the var has not been synchronized
    released             the device copy was freed by ``Release``

Happens-before edges mirror the executor/backends exactly: transfers on
one logical stream are FIFO; ``Synchronize(stream=s)`` completes every
upload whose stream folds onto the same physical queue as ``s`` *and*
all stream-0 compute (``do_sync`` waits both); a callsite completes its
OWN group's in-flight transfers (HMPP: codelet arguments are group
buffers — the launch depends on them), which is why a pipelined plan
with asynchronous loads and no pre-callsite sync is race-free while a
cross-group or re-streamed mutant is not; downloads are synchronous
wait points (``np.asarray`` forces the value).

Violation taxonomy (``Violation.kind``):

    ``async-race``        error — a device read of an upload still in
                          flight on another group's stream, or a
                          download of an async callsite's result with
                          no intervening ``Synchronize``
    ``stale-host-read``   error — a host block (or the program's
                          declared outputs) reads a var whose only
                          up-to-date copy is device-dirty (missing
                          ``DelegateStore``)
    ``use-after-release`` error — a device read/download of a var whose
                          device copy ``Release`` freed
    ``use-after-donation``error — with donation in effect, an offload
                          block rewrites a buffer whose upload is still
                          in flight: the fused launch recycles the
                          buffer under an active DMA
    ``placement-gap``     error — a read with no valid copy anywhere
                          (a deleted/misplaced transfer)
    ``illegal-kernel-tile``error — a kernel-tagged block launched with
                          a tile the registry (``kernels/variants``)
                          rejects for its operand shapes, or an unknown
                          kernel name
    ``mesh-placement``    error — a sharded plan whose placement record
                          is inconsistent: a spec naming a variable the
                          program does not have, a mesh axis the mesh
                          does not declare, a sharded dim the axis size
                          does not divide (the divisibility guard
                          should have dropped it), or a
                          divisibility-guard drop whose variable then
                          has no spec at all (a drop must leave the
                          var explicitly replicated, never a placement
                          gap)
    ``redundant-directive``LINT — duplicate uploads, dead stores,
                          uploads of never-device-read vars (the
                          paper's 3MM "E needs no upload" insight,
                          enforced).  Lints never fail verification:
                          the naive policy keeps its redundant
                          transfers by design.
    ``malformed``         error — structural corruption (unbalanced
                          loops, out-of-range block indices, empty
                          directive slots)

Every violation is op-indexed (``Violation.op_index`` is the position
in ``plan.ops``; ``len(plan.ops)`` means "at program end").  The walk
is best-effort: a violation is recorded, the abstract state repaired,
and checking continues, so one missing transfer reports once instead
of cascading.

This module is deliberately light on imports (no jax): kernel-tile
checks go through the stdlib-only ``repro.kernels.variants`` registry
and operand shapes come from the caller (``shapes=`` — the analyzer's
var → ShapeDtypeStruct map) or from the program's bound inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from .ir import (AdvancedLoad, BlockKind, Callsite, DelegateStore, GroupDecl,
                 Plan, PlanExecutionError, Release, Synchronize)

__all__ = ["Violation", "VerifyReport", "PlanVerificationError",
           "verify_plan", "VIOLATION_KINDS"]

VIOLATION_KINDS = (
    "async-race", "stale-host-read", "use-after-release",
    "use-after-donation", "placement-gap", "illegal-kernel-tile",
    "mesh-placement", "redundant-directive", "malformed",
)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: ``kind`` from ``VIOLATION_KINDS``, ``severity`` is
    "error" or "lint", ``op_index`` the position in ``plan.ops`` the
    finding anchors to (``len(plan.ops)`` = program end)."""
    kind: str
    severity: str
    op_index: int
    var: Optional[str]
    message: str

    def __str__(self) -> str:
        return (f"[{self.severity}] {self.kind} @op{self.op_index}"
                + (f" var={self.var!r}" if self.var else "")
                + f": {self.message}")


class PlanVerificationError(PlanExecutionError):
    """Raised by ``VerifyReport.raise_if_failed`` — carries the report.

    Subclasses ``PlanExecutionError``: a plan the verifier rejects is a
    plan that cannot execute, so callers guarding ``execute()`` with
    ``except PlanExecutionError`` behave identically whether the failure
    is caught statically (``REPRO_VERIFY=1``) or at runtime.
    """

    def __init__(self, report: "VerifyReport"):
        self.report = report
        super().__init__(report.summary())


@dataclasses.dataclass
class VerifyReport:
    """Outcome of ``verify_plan``: all findings, error/lint split, and a
    JSON-safe ``meta_record()`` for ``plan.meta["verify"]``."""
    plan_name: str
    checked_ops: int
    violations: List[Violation] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[Violation]:
        return [v for v in self.violations if v.severity == "error"]

    @property
    def lints(self) -> List[Violation]:
        return [v for v in self.violations if v.severity == "lint"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.violations:
            out[v.kind] = out.get(v.kind, 0) + 1
        return out

    def kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({v.kind for v in self.violations}))

    def summary(self) -> str:
        if not self.violations:
            return (f"plan {self.plan_name!r} verified: "
                    f"{self.checked_ops} ops, no findings")
        head = (f"plan {self.plan_name!r}: {len(self.errors)} error(s), "
                f"{len(self.lints)} lint(s) over {self.checked_ops} ops")
        return "\n".join([head] + [f"  {v}" for v in self.violations])

    def meta_record(self) -> Dict[str, Any]:
        """The compact record planners attach as ``plan.meta["verify"]``
        (see ``ir.Plan``): counts only — the full diagnostics stay on
        the report object."""
        return {"ok": self.ok, "checked_ops": self.checked_ops,
                "n_errors": len(self.errors), "n_lints": len(self.lints),
                "counts": self.counts()}

    def raise_if_failed(self) -> "VerifyReport":
        if not self.ok:
            raise PlanVerificationError(self)
        return self


# --------------------------------------------------------------------------
# Abstract machine.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _VarState:
    host: bool = False
    device: bool = False
    dirty: bool = False                 # device copy newer than host copy
    inflight: Optional[Tuple[int, int]] = None   # (stream, group) upload
    async_producer: Optional[int] = None         # op idx of unsynced write
    released: bool = False              # device copy freed by Release


def _phys_stream(stream: int, n_streams: int) -> int:
    """Logical → physical stream folding, mirroring
    ``Backend._stream_of``: stream 0 is the compute stream, transfer
    streams 1..∞ fold onto 1..n_streams."""
    if stream == 0:
        return 0
    return 1 + (stream - 1) % max(n_streams, 1)


def _group_vars_of(p: Plan) -> Dict[int, set]:
    """group id → vars it owns (mapbyname + member codelet reads/writes)
    — what a ``Release`` of that group frees (``executor.group_vars``)."""
    out: Dict[int, set] = {}
    for d in p.directives(GroupDecl):
        out.setdefault(d.group, set()).update(d.mapbyname)
    for g, idxs in p.groups.items():
        names = out.setdefault(g, set())
        for bi in idxs:
            blk = p.program.blocks[bi]
            names.update(blk.reads)
            names.update(blk.writes)
    return out


def _input_shapes(p: Plan) -> Dict[str, Any]:
    """Fallback operand shapes from the program's bound inputs (concrete
    arrays or ShapeDtypeStructs both expose .shape/.dtype)."""
    out = {}
    for k, v in p.program.inputs.items():
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            out[k] = v
    return out


def _check_kernel_tiles(p: Plan, kernel_variants, shapes, emit) -> None:
    """Kernel-tile legality for every kernel-tagged block against the
    ``kernels/variants`` registry, at the block's op index.  Blocks whose
    operand shapes are unknown are skipped (nothing to validate against)."""
    from repro.kernels.variants import KERNELS, validate_variant
    kv = {str(k): dict(v) for k, v in dict(kernel_variants or {}).items()}
    shapes = dict(shapes or {})
    for i, op in enumerate(p.ops):
        if op.kind != "block":
            continue
        blk = p.program.blocks[op.block_idx]
        kernel = getattr(blk, "kernel", None)
        if not kernel:
            continue
        if kernel not in KERNELS:
            emit("illegal-kernel-tile", "error", i, None,
                 f"block {blk.name!r} is tagged with unknown kernel "
                 f"{kernel!r} (registry: {sorted(KERNELS)})")
            continue
        try:
            op_shapes = [tuple(shapes[v].shape) for v in blk.reads]
        except (KeyError, AttributeError, TypeError):
            continue             # operand shapes unknown — cannot judge
        params = kv.get(kernel) or dict(KERNELS[kernel]["defaults"])
        missing = [n for n in KERNELS[kernel]["defaults"] if n not in params]
        if missing:
            emit("illegal-kernel-tile", "error", i, None,
                 f"kernel {kernel!r} variant {params} is missing tile "
                 f"parameter(s) {missing}")
            continue
        try:
            v = validate_variant(kernel, op_shapes, params)
        except Exception as e:
            emit("illegal-kernel-tile", "error", i, None,
                 f"kernel {kernel!r} variant {params} rejected: {e}")
            continue
        if v is None:
            emit("illegal-kernel-tile", "error", i, None,
                 f"kernel {kernel!r} tile {params} is illegal for operand "
                 f"shapes {op_shapes} (non-dividing after clamping)")


def _check_mesh_placement(p: Plan, mesh: Dict[str, Any],
                          shapes: Optional[Dict[str, Any]], emit) -> set:
    """Validate a sharded plan's placement record (``meta["mesh"]``).

    The record is the plain-JSON dict ``tuner._mesh_record`` writes —
    ``shape``/``axes`` (the mesh), ``specs`` (var → PartitionSpec
    entries) and ``dropped`` (the divisibility-guard log) — so this
    stays jax-free.  Returns the set of *sharded* variables (any
    non-None spec entry): the state walk treats consuming a sharded
    operand as a cross-device sync point.
    """
    end = len(p.ops)
    sizes = dict(zip(tuple(mesh.get("axes") or ()),
                     tuple(mesh.get("shape") or ())))
    specs = mesh.get("specs") or {}
    program = p.program
    known = set(program.inputs)
    for blk in program.blocks:
        known.update(blk.reads)
        known.update(blk.writes)
    if shapes:
        known.update(shapes)
    sharded: set = set()
    for var, entries in sorted(specs.items()):
        if var not in known:
            emit("mesh-placement", "error", end, var,
                 f"placement spec names {var!r}, which no program block "
                 "reads or writes and no input binds")
            continue
        dims = None
        sv = (shapes or {}).get(var)
        if sv is not None and hasattr(sv, "shape"):
            dims = tuple(sv.shape)
        entries = tuple(entries or ())
        if dims is not None and len(entries) > len(dims):
            emit("mesh-placement", "error", end, var,
                 f"spec {entries!r} has more entries than {var!r}'s "
                 f"rank {len(dims)}")
            continue
        for d, e in enumerate(entries):
            if e is None:
                continue
            names = tuple(e) if isinstance(e, (list, tuple)) else (e,)
            factor, bad = 1, False
            for a in names:
                if a not in sizes:
                    emit("mesh-placement", "error", end, var,
                         f"spec shards {var!r} dim {d} over mesh axis "
                         f"{a!r}, which mesh {sizes!r} does not declare")
                    bad = True
                    break
                factor *= int(sizes[a])
            if bad:
                continue
            sharded.add(var)
            if dims is not None and factor and dims[d] % factor != 0:
                emit("mesh-placement", "error", end, var,
                     f"spec shards {var!r} dim {d} (size {dims[d]}) over "
                     f"{names!r} ({factor} shards), which does not divide "
                     "it — the divisibility guard should have dropped "
                     "this entry")
    for rec in (mesh.get("dropped") or ()):
        ctx = rec[0] if rec else None
        if ctx is not None and str(ctx) not in specs:
            emit("mesh-placement", "error", end, str(ctx),
                 f"divisibility guard dropped an axis of {ctx!r} but the "
                 "placement carries no spec for it at all — a drop must "
                 "leave the var explicitly replicated, not a gap")
    return sharded


# --------------------------------------------------------------------------
# The verifier walk.
# --------------------------------------------------------------------------

def verify_plan(p: Plan, *, donate: Optional[bool] = None,
                kernel_variants: Optional[Dict[str, Dict[str, int]]] = None,
                shapes: Optional[Dict[str, Any]] = None,
                collect_lints: bool = True,
                mesh: Optional[Dict[str, Any]] = None) -> VerifyReport:
    """Statically verify ``p``; returns a ``VerifyReport`` (never raises
    for plan defects — call ``.raise_if_failed()`` for the hard-error
    contract).

    ``donate``            whether buffer donation is in effect for the
                          execution being vetted (None → the plan's own
                          ``meta["donate"]``)
    ``kernel_variants``   {kernel: {param: value}} tile choice for
                          kernel-tagged blocks (None → the plan's
                          ``meta["kernel_variants"]``, else registry
                          defaults)
    ``shapes``            var → shaped value (the analyzer's
                          ShapeDtypeStruct map); falls back to the
                          program's bound inputs
    ``collect_lints``     False skips the redundancy lints (the tuner
                          verifies many candidates and only needs the
                          error verdict)
    ``mesh``              a sharded plan's placement record (the
                          ``meta["mesh"]`` dict written by the tuner:
                          shape/axes/specs/dropped); None → the plan's
                          own ``meta["mesh"]``.  When present, specs
                          are validated (``mesh-placement``) and a
                          sharded operand's consumption counts as a
                          cross-device sync point in the race walk
    """
    program = p.program
    ops = p.ops
    report = VerifyReport(plan_name=program.name, checked_ops=len(ops))
    seen: set = set()

    def emit(kind: str, severity: str, idx: int, var: Optional[str],
             message: str) -> None:
        key = (kind, idx, var)
        if key in seen:
            return
        seen.add(key)
        report.violations.append(Violation(kind, severity, idx, var,
                                           message))

    if donate is None:
        donate = bool(p.meta.get("donate", False))
    if kernel_variants is None:
        kernel_variants = p.meta.get("kernel_variants") or {}
    if mesh is None:
        mesh = p.meta.get("mesh")
    n_streams = int(p.meta.get("n_transfer_streams", 0) or 0)

    # -- structural pass (malformed plans do not get a state walk) ----------
    spans: Dict[int, Tuple[int, int]] = {}
    stack: List[Tuple[int, int]] = []
    malformed = False
    for i, op in enumerate(ops):
        if op.kind == "loop_begin":
            if op.loop_id not in program.loops:
                emit("malformed", "error", i, None,
                     f"loop_begin references unknown loop {op.loop_id}")
                malformed = True
                continue
            stack.append((op.loop_id, i))
        elif op.kind == "loop_end":
            if not stack or stack[-1][0] != op.loop_id:
                emit("malformed", "error", i, None,
                     f"loop_end({op.loop_id}) does not match the open "
                     f"loop nest {[lid for lid, _ in stack]}")
                malformed = True
                continue
            lid, start = stack.pop()
            spans[lid] = (start, i)
        elif op.kind == "block":
            if op.block_idx is None or not (
                    0 <= op.block_idx < len(program.blocks)):
                emit("malformed", "error", i, None,
                     "block op references out-of-range block "
                     f"{op.block_idx}")
                malformed = True
        elif op.kind == "directive":
            if op.directive is None:
                emit("malformed", "error", i, None,
                     "directive op carries no directive")
                malformed = True
        else:
            emit("malformed", "error", i, None,
                 f"unknown plan-op kind {op.kind!r}")
            malformed = True
    for lid, start in stack:
        emit("malformed", "error", start, None,
             f"loop_begin({lid}) is never closed")
        malformed = True
    if malformed:
        return report

    shapes = shapes or _input_shapes(p)
    _check_kernel_tiles(p, kernel_variants, shapes, emit)
    sharded_vars: set = set()
    if mesh:
        sharded_vars = _check_mesh_placement(p, mesh, shapes, emit)

    # -- abstract state -----------------------------------------------------
    state: Dict[str, _VarState] = {
        v: _VarState(host=True) for v in program.inputs
    }
    group_of_block: Dict[int, int] = {}
    for g, idxs in p.groups.items():
        for bi in idxs:
            group_of_block[bi] = g
    pending_callsite: Dict[int, Callsite] = {}
    release_vars = _group_vars_of(p)

    # lint bookkeeping: per-op redundancy flags (loop bodies run twice, a
    # lint fires only when EVERY execution of the op was redundant — the
    # same all-executions rule ``simulate`` uses for elision)
    load_hits: Dict[int, List[bool]] = {}
    store_hits: Dict[int, List[bool]] = {}
    load_was_read: Dict[int, bool] = {}      # upload op -> value device-read
    store_was_used: Dict[int, bool] = {}     # store op -> host value used
    last_load_op: Dict[str, Optional[int]] = {}
    last_store_op: Dict[str, Optional[int]] = {}

    def vstate(v: str) -> _VarState:
        return state.setdefault(v, _VarState())

    def note_device_read(v: str) -> None:
        li = last_load_op.get(v)
        if li is not None:
            load_was_read[li] = True

    def note_host_read(v: str) -> None:
        si = last_store_op.get(v)
        if si is not None:
            store_was_used[si] = True

    def do_directive(i: int, d) -> None:
        if isinstance(d, AdvancedLoad):
            st = vstate(d.var)
            if not st.host:
                emit("placement-gap", "error", i, d.var,
                     f"advancedload of {d.var!r} but no valid host copy "
                     "exists (missing upstream delegatedstore or "
                     "producer)")
                st.host = True           # repair and continue
            if collect_lints:
                load_hits.setdefault(i, []).append(
                    st.device and not st.dirty)
                load_was_read.setdefault(i, False)
            st.device, st.dirty, st.released = True, False, False
            st.inflight = ((d.stream, d.group) if d.asynchronous else None)
            last_load_op[d.var] = i
        elif isinstance(d, DelegateStore):
            st = vstate(d.var)
            if st.released and not st.device:
                emit("use-after-release", "error", i, d.var,
                     f"delegatedstore of {d.var!r} after its group's "
                     "release freed the device copy")
                st.device = True
            elif not st.device:
                emit("placement-gap", "error", i, d.var,
                     f"delegatedstore of {d.var!r} but no valid device "
                     "copy exists")
                st.device = True
            # d2h is a wait point for the stored handle itself
            # (``Backend.download`` blocks until the value is ready), so a
            # pending async upload or callsite of *this* var is completed
            # here, not raced — HMPP would want an explicit synchronize,
            # which the planner always emits, but its absence is safe
            # under this runtime and must not fail hand-mutated plans
            st.inflight = None
            st.async_producer = None
            if collect_lints:
                store_hits.setdefault(i, []).append(
                    st.host and not st.dirty)
                store_was_used.setdefault(i, False)
            note_device_read(d.var)
            st.host, st.dirty = True, False
            last_store_op[d.var] = i
        elif isinstance(d, Synchronize):
            ph = _phys_stream(d.stream, n_streams or 1)
            for st in state.values():
                if st.inflight is not None:
                    s_ph = (_phys_stream(st.inflight[0], n_streams)
                            if n_streams else st.inflight[0])
                    d_ph = (ph if n_streams else d.stream)
                    if s_ph == d_ph:
                        st.inflight = None
                st.async_producer = None     # do_sync also waits stream 0
        elif isinstance(d, Release):
            freed = release_vars.get(d.group, set())
            for v in freed:
                st = vstate(v)
                # the runtime frees only vars with a valid host copy
                # (do_release never drops the sole copy of a value)
                if st.host and st.device:
                    st.device, st.dirty = False, False
                    st.inflight = None
                    st.released = True
        elif isinstance(d, Callsite):
            pending_callsite[d.block_idx] = d

    def do_block(i: int, bidx: int) -> None:
        blk = program.blocks[bidx]
        if blk.kind is BlockKind.OFFLOAD:
            cs = pending_callsite.pop(bidx, None)
            group = (cs.group if cs is not None
                     else group_of_block.get(bidx, 0))
            asynchronous = cs.asynchronous if cs is not None else True
            # the launch depends on its own group's buffers: HMPP
            # completes that group's in-flight transfers here
            for st in state.values():
                if st.inflight is not None and st.inflight[1] == group:
                    st.inflight = None
            reads = set(blk.effective_reads())
            # snapshot uploads still in flight at launch entry: the reads
            # walk below clears ``inflight`` as it reports races, but the
            # donation check needs to know the DMA was live when the
            # donated buffer gets recycled
            dma_live = {v: vstate(v).inflight for v in blk.writes
                        if vstate(v).inflight is not None}
            for v in sorted(reads):
                st = vstate(v)
                # a sharded operand's dispatch waits on every shard of
                # the distributed upload before the SPMD computation
                # (and its collectives) can run: the collective is a
                # cross-device sync point, so the in-flight DMA cannot
                # race the read
                if v in sharded_vars:
                    st.inflight = None
                if st.inflight is not None:
                    emit("async-race", "error", i, v,
                         f"codelet {blk.name!r} reads {v!r} while its "
                         "upload is still in flight on stream "
                         f"{st.inflight[0]} (group {st.inflight[1]} != "
                         f"callsite group {group}) with no synchronize "
                         "on that stream")
                    st.inflight = None
                if not st.device:
                    if st.released:
                        emit("use-after-release", "error", i, v,
                             f"codelet {blk.name!r} reads {v!r} after "
                             "its group's release freed the device copy")
                    elif st.host:
                        emit("placement-gap", "error", i, v,
                             f"codelet {blk.name!r} reads {v!r}: not on "
                             "device (missing advancedload)")
                    else:
                        emit("placement-gap", "error", i, v,
                             f"codelet {blk.name!r} reads {v!r} but no "
                             "valid copy exists anywhere")
                    st.device = True
                note_device_read(v)
            for v in blk.writes:
                st = vstate(v)
                if donate and v in reads and v in dma_live:
                    emit("use-after-donation", "error", i, v,
                         f"donation rewrites {v!r} while its upload is "
                         f"still in flight on stream {dma_live[v][0]}: "
                         "the donated buffer is recycled under an "
                         "active DMA")
                st.device, st.dirty, st.host = True, True, False
                st.released = False
                st.inflight = None
                st.async_producer = i if asynchronous else None
                last_load_op[v] = None   # upload value overwritten
        else:
            for v in sorted(set(blk.effective_reads())):
                st = vstate(v)
                if not st.host:
                    if st.device:
                        emit("stale-host-read", "error", i, v,
                             f"host block {blk.name!r} reads {v!r} but "
                             "the only up-to-date copy is device-dirty "
                             "(missing delegatedstore)")
                    else:
                        emit("placement-gap", "error", i, v,
                             f"host block {blk.name!r} reads {v!r} but "
                             "no valid copy exists anywhere")
                    st.host = True
                note_host_read(v)
            for v in blk.writes:
                st = vstate(v)
                st.host, st.device, st.dirty = True, False, False
                st.inflight = None       # uploaded value now obsolete
                st.async_producer = None
                last_load_op[v] = None

    def exec_range(lo: int, hi: int) -> None:
        i = lo
        while i < hi:
            op = ops[i]
            if op.kind == "loop_begin":
                start, end = spans[op.loop_id]
                for _ in range(2):       # 2-iteration loop abstraction
                    exec_range(start + 1, end)
                i = end + 1
                continue
            if op.kind == "directive":
                do_directive(i, op.directive)
            elif op.kind == "block":
                do_block(i, op.block_idx)
            i += 1

    exec_range(0, len(ops))

    # -- program exit: declared outputs must be host-valid ------------------
    end = len(ops)
    for v in (program.outputs or ()):
        st = state.get(v)
        if st is None or not (st.host or st.device):
            emit("placement-gap", "error", end, v,
                 f"declared output {v!r} is never produced")
        elif not st.host:
            emit("stale-host-read", "error", end, v,
                 f"declared output {v!r} is not on the host at program "
                 "end (missing delegatedstore)")
        else:
            note_host_read(v)

    # -- redundancy lints ----------------------------------------------------
    if collect_lints:
        for i, flags in sorted(load_hits.items()):
            d = ops[i].directive
            if flags and all(flags):
                emit("redundant-directive", "lint", i, d.var,
                     f"duplicate upload: {d.var!r} is already "
                     "device-resident and unchanged on every execution "
                     "of this advancedload")
            elif not load_was_read.get(i, True):
                emit("redundant-directive", "lint", i, d.var,
                     "upload of never-read var: no codelet reads "
                     f"{d.var!r}'s uploaded value before it is "
                     f"overwritten ({d.var!r} needs no advancedload)")
        for i, flags in sorted(store_hits.items()):
            d = ops[i].directive
            if flags and all(flags):
                emit("redundant-directive", "lint", i, d.var,
                     f"duplicate store: the host copy of {d.var!r} is "
                     "already current on every execution of this "
                     "delegatedstore")
            elif not store_was_used.get(i, True):
                emit("redundant-directive", "lint", i, d.var,
                     "dead store: no host read or declared output "
                     f"consumes {d.var!r}'s downloaded value")
    return report
