"""Persistent plan-space tuning cache (ISSUE 5).

The PR-3 explorer re-measures the full candidate grid on every
``plan(p, policy="auto")`` call.  The sequel paper (arXiv:1506.02833)
makes the point that the exploration must be cheap and *repeatable* to
be usable: this module keys each tuning result on a content fingerprint
of everything the result depends on —

    program ops        block bodies (bytecode), reads/writes, loop nest,
                       input shapes/dtypes, declared outputs
    backend identity   class, registered name, stream count, donation
                       flag, device
    candidate grid     the exact config list plus the measurement
                       protocol (top_k, reps)
    cost model         ``COST_MODEL_VERSION`` + the default hardware
                       constants the predictions were priced with

— so a repeated ``policy="auto"`` call returns the cached winner (and
the byte-identical ranked table) without re-measuring, while ANY change
to the program, the backend, the grid, or the cost model misses.

Entries are one JSON file per (program name, backend, grid+protocol)
slot — distinct grids/protocols of the same program coexist instead of
evicting each other — while the FULL fingerprint is stored inside the
entry and checked on lookup, so a genuinely stale entry (program edited
in place, cost-model version bumped) is evicted rather than reused.
``tune(refresh=True)`` bypasses lookup and overwrites.

The cache also owns a per-DEVICE-CLASS store (``device_class_key`` —
stream count and donation flag deliberately excluded, they are candidate
knobs, not silicon): the *measured calibration* of the cost model
(fitted ``pcie_bw`` / ``launch_overhead_s`` / ``sync_overhead_s``, see
``repro.roofline.analysis.fit_offload_constants``), every program's
measured candidate rows, and the cross-program cold-start predictor
fitted from them (ISSUE 10) — so constants and rankings learned while
tuning one program price the next, never-measured one.

Location: the ``REPRO_TUNE_CACHE`` env var (empty/"off"/"0" disables
caching), else ``$XDG_CACHE_HOME/repro/tunecache``.  This module is
deliberately stdlib-only so CI can probe ``COST_MODEL_VERSION`` without
importing the JAX stack.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import re
import tempfile
from typing import Any, Dict, Optional, Sequence

__all__ = [
    "COST_MODEL_VERSION", "TuneCache", "default_cache",
    "program_fingerprint", "backend_fingerprint", "grid_fingerprint",
    "tuning_fingerprint", "calibration_fingerprint", "device_class_key",
]

# Bump whenever predict_cost / offload_cost_terms semantics change: every
# cached table and every fitted calibration is invalidated by the bump.
# v1 was the PR-3 tuner (no cache); v2 adds dominance pruning + hw= pricing;
# v3 adds the kernel-variant axis and the two-level (PCIe + HBM) roofline;
# v4 adds the mesh placement axis and interconnect (ici_bw) cost terms;
# v5 adds the energy / peak-device-bytes objectives and the cross-program
# candidate predictor (ISSUE 10) — bumping also clears the per-device-class
# store (calibration + measured rows + predictor).
COST_MODEL_VERSION = 5

_ENV_VAR = "REPRO_TUNE_CACHE"
_MAX_ENV_VAR = "REPRO_TUNE_CACHE_MAX"
_DISABLED = ("", "0", "off", "none")
_DEFAULT_MAX_ENTRIES = 256


def _sha(obj: Any) -> str:
    blob = json.dumps(obj, sort_keys=True, default=repr).encode()
    return hashlib.sha256(blob).hexdigest()


def _cell_key(value: Any) -> Any:
    """Key for one closure-cell value.  repr alone is NOT enough for
    arrays — numpy truncates > 1000 elements shapelessly, so two
    different-sized captured weight arrays would repr identically and
    alias a stale cache entry; shape/dtype are keyed explicitly."""
    shape = getattr(value, "shape", None)
    if shape is not None:
        return ["array", list(shape),
                str(getattr(value, "dtype", "")), repr(value)]
    return repr(value)


def _code_key(fn) -> Any:
    """Content key for a block body: bytecode + consts + names, so an
    edited kernel invalidates while re-building the identical lambda
    does not.  Closure cell values are included (a captured scalar or
    array changing the computation must change the key)."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return repr(fn)
    cells = tuple(_cell_key(getattr(c, "cell_contents", None))
                  for c in (fn.__closure__ or ()))
    return [code.co_code.hex(), repr(code.co_consts), code.co_names,
            code.co_varnames, code.co_argcount, code.co_freevars, cells]


def program_fingerprint(program) -> str:
    """Content hash of the tuning-relevant program structure.  Input
    *values* are excluded on purpose — timings depend on shapes and
    dtypes, not on the numbers in the arrays."""
    obj = {
        "name": program.name,
        "blocks": [[b.idx, b.kind.value, b.name, list(b.reads),
                    list(b.writes), list(b.loop_path), _code_key(b.fn),
                    getattr(b, "kernel", None)]
                   for b in program.blocks],
        "loops": [[lid, info.n_iters, list(info.parent_path)]
                  for lid, info in sorted(program.loops.items())],
        "inputs": [[k, list(getattr(v, "shape", ())),
                    str(getattr(v, "dtype", type(v).__name__))]
                   for k, v in sorted(program.inputs.items())],
        "outputs": list(program.outputs),
    }
    return _sha(obj)


def backend_fingerprint(backend) -> str:
    """Identity string for the measuring backend: two backends with the
    same fingerprint must time a plan the same way.  Mesh backends fold
    in the mesh shape + axis names — the same program tuned on a 2x4
    and a 1x8 mesh picks different placements, so the tables must not
    alias (per-candidate placement is part of the grid, not this)."""
    fp = (f"{type(backend).__name__}:{backend.name}"
          f":streams{backend.n_streams}"
          f":donate{getattr(backend, 'donate', False)}"
          f":{getattr(backend, '_device', None)}")
    mesh_key = getattr(backend, "mesh_key", None)
    if mesh_key:
        fp += f":mesh{mesh_key}"
    return fp


def grid_fingerprint(configs: Sequence, protocol: Dict[str, Any]) -> str:
    """Hash of the candidate grid + measurement protocol: part of the
    SLOT key (not just the fingerprint), so e.g. a ``top_k`` sweep and
    the default grid of the same program keep separate entries instead
    of evicting each other on every alternation."""
    return _sha({"grid": [c.as_dict() for c in configs],
                 "protocol": protocol})


def tuning_fingerprint(program, backend, configs: Sequence,
                       protocol: Dict[str, Any],
                       hw: Dict[str, float]) -> str:
    """The full cache key: see module docstring.  ``hw`` must be the
    DEFAULT pricing constants (never the calibrated ones — calibration
    drift must not evict measured tables, see tune())."""
    return _sha({
        "cost_model_version": COST_MODEL_VERSION,
        "program": program_fingerprint(program),
        "backend": backend_fingerprint(backend),
        "grid": [c.as_dict() for c in configs],
        "protocol": protocol,
        "hw": {k: hw[k] for k in sorted(hw)},
    })


def calibration_fingerprint(hw: Dict[str, float]) -> str:
    """Fitted constants are valid for one (cost-model version, default
    constants) pair; either changing discards them."""
    return _sha({"cost_model_version": COST_MODEL_VERSION,
                 "hw": {k: hw[k] for k in sorted(hw)}})


def device_class_key(backend) -> str:
    """Key of the per-DEVICE-CLASS store (calibration constants, measured
    candidate rows, fitted cross-program predictor).  Unlike
    ``backend_fingerprint`` it deliberately EXCLUDES the stream count and
    the donation flag: those are per-candidate knobs (features of a
    measured row), not properties of the silicon — a 4-stream and a
    2-stream run of the same device must pool their measurements rather
    than fit in separate slots (the PR 5/6 per-backend-slot bug)."""
    key = f"{type(backend).__name__}:{backend.name}" \
          f":{getattr(backend, '_device', None)}"
    mesh_key = getattr(backend, "mesh_key", None)
    if mesh_key:
        key += f":mesh{mesh_key}"
    return key


class TuneCache:
    """One JSON file per slot under ``path``; lookups validate the
    stored fingerprint and evict on mismatch (stale-entry invalidation).
    Writes are atomic (tempfile + rename).

    The cache is bounded: past ``max_entries`` slot files (default 256,
    or ``REPRO_TUNE_CACHE_MAX``), ``store`` evicts the least-recently
    used entries by file mtime — lookups touch their entry so a hot slot
    survives a cold sweep.  ``max_entries <= 0`` disables eviction."""

    def __init__(self, path: Optional[Any] = None,
                 max_entries: Optional[int] = None):
        if max_entries is None:
            try:
                max_entries = int(os.environ.get(
                    _MAX_ENV_VAR, _DEFAULT_MAX_ENTRIES))
            except ValueError:
                max_entries = _DEFAULT_MAX_ENTRIES
        self.max_entries = max_entries
        if path is None:
            env = os.environ.get(_ENV_VAR)
            # a disable sentinel is not a directory name: a direct
            # TuneCache() under REPRO_TUNE_CACHE=off must not create a
            # literal ./off — fall through to the XDG default (callers
            # wanting the sentinel honored use default_cache())
            if env and env.strip().lower() not in _DISABLED:
                path = env
            else:
                xdg = os.environ.get("XDG_CACHE_HOME",
                                     os.path.expanduser("~/.cache"))
                path = os.path.join(xdg, "repro", "tunecache")
        self.path = pathlib.Path(path)

    # -- internals ----------------------------------------------------------
    def _slot_path(self, slot: str) -> pathlib.Path:
        safe = re.sub(r"[^A-Za-z0-9._-]+", "_", slot)[:48]
        return self.path / f"{safe}-{_sha(slot)[:16]}.json"

    # -- tuning entries -----------------------------------------------------
    def lookup(self, slot: str, fingerprint: str) -> Optional[Dict]:
        """The payload stored for ``slot`` iff its fingerprint matches;
        a stale entry is deleted and reported as a miss."""
        fp_path = self._slot_path(slot)
        try:
            entry = json.loads(fp_path.read_text())
        except (OSError, ValueError):
            return None
        if entry.get("fingerprint") != fingerprint:
            try:
                fp_path.unlink()
            except OSError:
                pass
            return None
        try:
            os.utime(fp_path)  # LRU recency: a hit keeps the entry warm
        except OSError:
            pass
        return entry.get("payload")

    def evict(self, slot: str) -> None:
        """Drop ``slot``'s entry (used when a stored payload is corrupt
        or its rebuilt winner no longer passes the plan verifier — the
        fingerprint cannot see inside the payload, so the verifier is
        the load-time integrity check)."""
        try:
            self._slot_path(slot).unlink()
        except OSError:
            pass

    def store(self, slot: str, fingerprint: str, payload: Dict) -> None:
        self.path.mkdir(parents=True, exist_ok=True)
        entry = {"slot": slot, "fingerprint": fingerprint,
                 "cost_model_version": COST_MODEL_VERSION,
                 "payload": payload}
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(entry, f, indent=1, sort_keys=True, default=float)
            os.replace(tmp, self._slot_path(slot))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._evict_lru(keep=self._slot_path(slot))

    def _evict_lru(self, keep: Optional[pathlib.Path] = None) -> None:
        """Delete oldest-mtime entries until at most ``max_entries``
        remain.  The just-written slot (``keep``) is never evicted even
        when the cap is smaller than one."""
        if self.max_entries is None or self.max_entries <= 0:
            return
        try:
            files = list(self.path.glob("*.json"))
        except OSError:
            return
        if len(files) <= self.max_entries:
            return

        def _mtime(f: pathlib.Path) -> float:
            try:
                return f.stat().st_mtime
            except OSError:
                return float("inf")  # vanished: skip, don't evict for it

        files.sort(key=_mtime)
        excess = len(files) - self.max_entries
        for f in files:
            if excess <= 0:
                break
            if keep is not None and f == keep:
                continue
            try:
                f.unlink()
            except OSError:
                pass
            excess -= 1

    # -- per-device-class store (ISSUE 10) ----------------------------------
    # One slot per device class (``device_class_key``) holding everything
    # measurement-derived the class accumulates across programs:
    #   {"calibration": fitted constants | absent,
    #    "programs":    {program_fp: {"program": name, "rows": [...]}},
    #    "predictor":   fitted cross-program model | absent}
    # Previously calibration lived in per-BACKEND slots, so the same
    # device fitted (and read) different constants at each stream count —
    # the carried-over PR 5/6 bug this store fixes.  Fingerprinted on
    # (COST_MODEL_VERSION, default hw): either changing drops the slot.

    _MAX_DEVCLASS_PROGRAMS = 32

    def _load_devclass(self, device_key: str,
                       hw: Dict[str, float]) -> Dict[str, Any]:
        payload = self.lookup(f"devclass--{device_key}",
                              calibration_fingerprint(hw))
        return dict(payload) if isinstance(payload, dict) else {}

    def _store_devclass(self, device_key: str, hw: Dict[str, float],
                        payload: Dict[str, Any]) -> None:
        self.store(f"devclass--{device_key}",
                   calibration_fingerprint(hw), payload)

    def load_calibration(self, device_key: str,
                         hw: Dict[str, float]) -> Optional[Dict[str, float]]:
        return self._load_devclass(device_key, hw).get("calibration")

    def store_calibration(self, device_key: str, hw: Dict[str, float],
                          fitted: Dict[str, float]) -> None:
        payload = self._load_devclass(device_key, hw)
        payload["calibration"] = fitted
        self._store_devclass(device_key, hw, payload)

    def add_measured_rows(self, device_key: str, hw: Dict[str, float],
                          program_fp: str, program_name: str,
                          rows: Sequence[Dict[str, Any]]) -> None:
        """Record one program's measured candidate rows (feature dicts,
        see ``roofline.analysis.candidate_features``) under the device
        class.  Re-tuning the same program replaces its rows; past
        ``_MAX_DEVCLASS_PROGRAMS`` programs the oldest entry is dropped
        (insertion order — dicts preserve it, JSON round-trips it)."""
        if not rows:
            return
        payload = self._load_devclass(device_key, hw)
        progs = payload.setdefault("programs", {})
        progs.pop(program_fp, None)
        progs[program_fp] = {"program": program_name, "rows": list(rows)}
        while len(progs) > self._MAX_DEVCLASS_PROGRAMS:
            del progs[next(iter(progs))]
        self._store_devclass(device_key, hw, payload)

    def load_measured_rows(self, device_key: str, hw: Dict[str, float],
                           exclude_fp: Optional[str] = None
                           ) -> list:
        """Every stored row across the class's programs — the predictor's
        training set.  ``exclude_fp`` drops the program being tuned, so
        pricing its grid is always a hold-one-out prediction."""
        progs = self._load_devclass(device_key, hw).get("programs") or {}
        rows = []
        for fp, entry in progs.items():
            if fp == exclude_fp:
                continue
            for row in entry.get("rows", ()):
                r = dict(row)
                r.setdefault("program", entry.get("program", fp))
                rows.append(r)
        return rows

    def load_predictor(self, device_key: str,
                       hw: Dict[str, float]) -> Optional[Dict[str, Any]]:
        return self._load_devclass(device_key, hw).get("predictor")

    def store_predictor(self, device_key: str, hw: Dict[str, float],
                        model: Dict[str, Any]) -> None:
        payload = self._load_devclass(device_key, hw)
        payload["predictor"] = model
        self._store_devclass(device_key, hw, payload)

    def clear(self) -> None:
        if self.path.is_dir():
            for f in self.path.glob("*.json"):
                try:
                    f.unlink()
                except OSError:
                    pass


def default_cache() -> Optional[TuneCache]:
    """Process default: honors ``REPRO_TUNE_CACHE`` (set a directory to
    relocate, empty/"off" to disable)."""
    env = os.environ.get(_ENV_VAR)
    if env is not None and env.strip().lower() in _DISABLED:
        return None
    return TuneCache()
