"""Distributed-optimization collectives: hierarchical gradient sync with
int8 compression + error feedback for the slow cross-pod hop.

On a (pod, data, model) mesh the gradient all-reduce decomposes as
    reduce within pod (fast ICI)  →  all-reduce across pods (slow DCI).
``hierarchical_psum_compressed`` keeps the intra-pod reduction in bf16/fp32
and quantizes only the cross-pod leg to int8 with a per-tensor scale;
``ErrorFeedback`` carries the quantization residual into the next step
(Seide et al., 2014 — 1-bit SGD lineage), which restores convergence to
uncompressed quality (tested in tests/test_collectives.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["quantize_int8", "dequantize_int8", "psum_compressed",
           "hierarchical_psum_compressed", "ErrorFeedback",
           "grad_sync_shard_map"]


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def psum_compressed(x, axis_name: str):
    """int8-compressed psum over ``axis_name`` (inside shard_map): quantize,
    reduce in int32 (exact for ≤ 2^23 summands), dequantize with the
    summed-scale — an unbiased linear approximation since each shard
    contributes q_i·s_i and we use a shared max-scale via psum-max."""
    shared_scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0
    shared_scale = jnp.maximum(shared_scale, 1e-12)
    q = jnp.clip(jnp.round(x / shared_scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * shared_scale


def hierarchical_psum_compressed(x, *, pod_axis: str = "pod",
                                 data_axis: str = "data"):
    """Exact psum within the pod, int8-compressed psum across pods."""
    within = jax.lax.psum(x, data_axis)
    return psum_compressed(within, pod_axis)


class ErrorFeedback:
    """Residual carry for compressed gradients:  g̃ = C(g + e);
    e' = (g + e) − g̃.  State is a pytree like the grads."""

    @staticmethod
    def init(grads):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads, error, compress_fn):
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, error)
        compressed = jax.tree.map(compress_fn, corrected)
        new_error = jax.tree.map(lambda c, comp: c - comp,
                                 corrected, compressed)
        return compressed, new_error


def grad_sync_shard_map(mesh, *, compressed: bool = True):
    """Returns a function all-reducing a replicated-gradient pytree across
    the pod axis via shard_map (the cross-pod hop of the hierarchical
    scheme); used when the pod axis runs pure DP."""
    from jax.experimental.shard_map import shard_map

    axis = "pod"
    if axis not in mesh.shape:
        return lambda g: g

    def sync_leaf(g):
        spec = P(*([None] * g.ndim))

        def body(gl):
            if compressed:
                return psum_compressed(gl, axis) / mesh.shape[axis]
            return jax.lax.psum(gl, axis) / mesh.shape[axis]

        return shard_map(body, mesh=mesh, in_specs=(spec,),
                         out_specs=spec, check_rep=False)(g)

    return lambda grads: jax.tree.map(sync_leaf, grads)
