"""Logical-axis → mesh-axis sharding rules.

Model code tags every param dim with a logical axis name (see
``models/layers.py::P``); here those names map to mesh axes per *shape kind*
(train / prefill / decode).  A **divisibility guard** drops any mesh axis
that does not evenly divide the dim (e.g. qwen2.5's 40 q-heads or Arctic's
56 on a 16-way "model" axis stay unsharded and the drop is recorded), so
every produced ``PartitionSpec`` is always valid for ``jax.jit``
in_shardings.

Parallelism layout (single pod 16×16, multi-pod 2×16×16):
  * batch        → ("pod", "data")      — DP across pods and data axis
  * embed        → "data"               — FSDP: params ZeRO-3-sharded over
                                          data; XLA all-gathers per layer
                                          and reduce-scatters grads
  * ffn/heads/vocab/experts/rnn → "model" — TP / EP
  * decode KV cache seq dim → "model"   — sequence-parallel decode
    (Flash-Decoding style: softmax stats all-reduce over "model")
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["ShardingRules", "make_rules", "spec_for_axes", "tree_shardings",
           "MeshPolicy", "batch_axes", "batch_specs", "cache_shardings",
           "abstract_mesh"]


def abstract_mesh(shape=(16, 16), axes=("data", "model")):
    """Device-less mesh for rule evaluation, across JAX versions: newer
    ``AbstractMesh`` takes ``(axis_sizes, axis_names)``, 0.4.x takes one
    ``((name, size), ...)`` tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


# logical axis -> mesh axis (or tuple), per shape kind
PARAM_RULES: Dict[str, Dict[str, Any]] = {
    "train": {
        "embed": "data",        # FSDP
        "embed_out": None,
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "vocab": "model",
        "experts": "model",
        "rnn": "model",
        "layers": None,
    },
    # inference: no FSDP (weights all-gathered once is wasteful per step);
    # keep TP on model, replicate the small rest
    "serve": {
        "embed": None,
        "embed_out": None,
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "vocab": "model",
        "experts": "model",
        "rnn": "model",
        "layers": None,
    },
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    kind: str                       # train | prefill | decode
    rules: Dict[str, Any]
    dropped: List[Tuple[str, str, int]] = dataclasses.field(
        default_factory=list)       # (context, axis, dim) divisibility drops

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            return int(np.prod([self.mesh.shape[a] for a in name]))
        return self.mesh.shape[name]


def make_rules(mesh: Mesh, kind: str, *,
               fsdp_layers: bool = False) -> ShardingRules:
    """``fsdp_layers``: shard stacked params on their LAYER dim over "data"
    instead of the embed dim (§Perf iteration: XLA then materializes only
    the current layer's slice per scan step instead of all-gathering the
    whole stack — the layers axis precedes embed in every stacked spec, so
    the divisibility-guarded used-set drops the embed rule there while
    unstacked params keep plain embed-FSDP)."""
    table = dict(PARAM_RULES["train" if kind == "train" else "serve"])
    if fsdp_layers:
        table["layers"] = "data"
    return ShardingRules(mesh=mesh, kind=kind, rules=table)


def spec_for_axes(rules: ShardingRules, shape: Tuple[int, ...],
                  axes: Tuple[Optional[str], ...],
                  context: str = "") -> PartitionSpec:
    """Build a valid PartitionSpec, dropping non-dividing mesh axes."""
    used = set()
    entries = []
    for dim, logical in zip(shape, axes):
        mesh_axis = rules.rules.get(logical) if logical else None
        if mesh_axis is None:
            entries.append(None)
            continue
        size = rules.axis_size(mesh_axis)
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if dim % size != 0 or any(a in used for a in flat):
            if dim % size != 0:
                rules.dropped.append((context, str(logical), dim))
            entries.append(None)
            continue
        used.update(flat)
        entries.append(mesh_axis)
    return PartitionSpec(*entries)


def tree_shardings(rules: ShardingRules, shapes_tree, axes_tree_,
                   context: str = "params"):
    """NamedSharding tree parallel to a ShapeDtypeStruct/array tree."""
    def one(leaf, axes):
        shape = leaf.shape if hasattr(leaf, "shape") else np.shape(leaf)
        spec = spec_for_axes(rules, tuple(shape), tuple(axes), context)
        return NamedSharding(rules.mesh, spec)
    return jax.tree.map(one, shapes_tree, axes_tree_,
                        is_leaf=lambda x: hasattr(x, "shape"))


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape) or None


def batch_specs(rules: ShardingRules, cfg, shape_kind: str,
                batch_shapes: Dict[str, Any]) -> Dict[str, NamedSharding]:
    """Shardings for the input batch: batch dim over (pod, data)."""
    b = batch_axes(rules.mesh)
    out = {}
    for name, sds in batch_shapes.items():
        nd = len(sds.shape)
        if sds.shape and sds.shape[0] % rules.axis_size(b) == 0:
            spec = PartitionSpec(b, *([None] * (nd - 1)))
        else:
            spec = PartitionSpec(*([None] * nd))
        out[name] = NamedSharding(rules.mesh, spec)
    return out


def cache_shardings(rules: ShardingRules, cache_tree):
    """Decode-cache shardings, chosen by the cache dict keys:

      k/v  (…, B, T, K, D) : batch→(pod,data), seq→model (sequence-parallel
                              decode — Flash-Decoding on TPU)
      pos  (…, B, T)       : matches k/v
      h    (…, B, D)       : batch→(pod,data), channel→model
      conv (…, B, w-1, D)  : batch→(pod,data), channel→model
      state(…, B, H, s, s) : batch→(pod,data), heads→model
      tm_x/cm_x (…, B, D)  : batch→(pod,data), channel→model

    All through the divisibility guard, so e.g. B=1 (long_500k) or H=40
    simply stay replicated."""
    mesh = rules.mesh
    b = batch_axes(mesh)

    # per-key: (offset from END of shape -> mesh axis)
    KEY_RULES = {
        "k":    {4: b, 3: "model"},
        "v":    {4: b, 3: "model"},
        "pos":  {2: b, 1: "model"},
        "h":    {2: b, 1: "model"},
        "conv": {3: b, 1: "model"},
        "state": {4: b, 3: "model"},
        "tm_x": {2: b, 1: "model"},
        "cm_x": {2: b, 1: "model"},
        "k_scale": {3: b, 2: "model"},
        "v_scale": {3: b, 2: "model"},
    }

    def one(path, leaf):
        key = None
        for entry in reversed(path):
            k = getattr(entry, "key", None)
            if isinstance(k, str):
                key = k
                break
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries: List[Any] = [None] * nd
        used: set = set()
        for off, ax in KEY_RULES.get(key, {}).items():
            i = nd - off
            if i < 0 or ax is None:
                continue
            size = rules.axis_size(ax)
            flat = set(ax) if isinstance(ax, tuple) else {ax}
            if shape[i] % size == 0 and not (flat & used):
                entries[i] = ax
                used |= flat
            else:
                rules.dropped.append((f"cache/{key}", str(ax), shape[i]))
        return NamedSharding(mesh, PartitionSpec(*entries))

    return jax.tree_util.tree_map_with_path(
        one, cache_tree, is_leaf=lambda x: hasattr(x, "shape"))


# ---------------------------------------------------------------------------
# Activation policy (with_sharding_constraint hints inside the model)
# ---------------------------------------------------------------------------

class MeshPolicy:
    """Maps the model's activation tags to PartitionSpecs.  Must run inside
    a mesh context (the dry-run and train loop do).

    ``seq_shard``: shard the residual stream on the SEQUENCE dim over
    "model" (Megatron-SP style) instead of the embed dim — norms and
    elementwise ops stay local, matmuls all-gather activations over seq
    and reduce-scatter back (§Perf iteration 'seqshard')."""

    def __init__(self, rules: ShardingRules, cfg, *, seq_shard: bool = False):
        self.rules = rules
        b = batch_axes(rules.mesh)
        m = "model"
        def div(n):
            return m if n % rules.axis_size(m) == 0 else None
        if seq_shard:
            emb_spec = PartitionSpec(b, m, None)
        else:
            # residual sharded over "model" on embed: keeps the per-layer
            # saved carries (scan + remat) within HBM at 48 layers
            emb_spec = PartitionSpec(b, None, div(cfg.d_model))
        nh = getattr(cfg, "n_heads", 0) or 1
        nkv = getattr(cfg, "n_kv_heads", 0) or 1
        self.table: Dict[str, PartitionSpec] = {
            # FSDP weight-gather hints: constrain layer weights to their
            # TP-only sharding at the point of use, so XLA all-gathers the
            # (small) weight slice over "data" instead of all-reducing the
            # (huge) activations over the FSDP-contracted dim
            # block inputs gathered ONCE per block in bf16 (shared by
            # q/k/v or gate/up): avoids per-dot fp32 partial-sum
            # all-reduces from contracting the D-sharded residual
            "block_in": PartitionSpec(b, None, None),
            "w_ffn_in": PartitionSpec(None, div(cfg.d_ff)),
            "w_ffn_out": PartitionSpec(div(cfg.d_ff), None),
            "w_attn_q": PartitionSpec(None, div(nh), None),
            "w_attn_kv": PartitionSpec(None, div(nkv), None),
            "w_attn_out": PartitionSpec(div(nh), None, None),
            "embeds": emb_spec,
            "embeds_dec": PartitionSpec(b, None, div(cfg.d_model)),
            "ffn_hidden": PartitionSpec(b, None, div(cfg.d_ff)),
            "rnn_hidden": PartitionSpec(b, None, div(cfg.d_model)),
            "q5": PartitionSpec(b, None,
                                div(getattr(cfg, "n_kv_heads", 0) or 1),
                                None, None),
            "kv4": PartitionSpec(b, None, None, None),
            "kvcache": PartitionSpec(b, m, None, None),
            "moe_buf": PartitionSpec(
                div(getattr(cfg, "n_experts", 0) or 1), None, None),
            "moe_hidden": PartitionSpec(
                div(getattr(cfg, "n_experts", 0) or 1), None, None),
        }

    def acts(self, x, kind: str):
        spec = self.table.get(kind)
        if spec is None:
            return x
        spec = PartitionSpec(*(spec[: x.ndim]))
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            return x
