"""Mesh-sharded execution backend — the OMP2MPI leap (ISSUE 9).

The paper's sibling tool OMP2MPI (arXiv:1502.02921) generated
*distributed* programs from the same pragma source OMP2HMPP compiled for
one accelerator.  This module is that leap for the plan runtime: a
``Backend`` whose ``AdvancedLoad``/``DelegateStore`` lower to **sharded**
uploads over a device mesh, so the same ``Plan`` that drove one GPU
drives an SPMD group — GSPMD inserts the collective schedule when the
jitted block bodies consume sharded operands.

Three pieces:

``MeshBackend``
    A ``JaxDeviceBackend`` over a ``jax.sharding.Mesh`` of every visible
    device (shape auto-derived, e.g. 8 devices → ``(2, 4)`` over
    ``("data", "model")``).  ``upload(host, name=...)`` places the array
    with ``NamedSharding(mesh, PartitionSpec(*placement[name]))`` — the
    per-variable placement the tuner chose; unmapped variables
    replicate.  ``with_placement`` returns a memoized twin per placement
    (jit caches shared per twin), and ``variant`` twins preserve the
    mesh + placement.

``placement_specs``
    Turns one placement *policy* (``replicate`` / ``fsdp`` / ``tp``)
    into per-variable ``PartitionSpec`` entries through
    ``distributed.sharding``'s divisibility-guarded logical-axis rules —
    fsdp shards dim 0 over "data" (logical ``embed``), tp shards the
    last dim over "model" (logical ``ffn``); non-dividing dims stay
    replicated with the drop recorded, so every spec is jit-valid.

``mesh_cost_terms``
    Prices a placement for the tuner without running it: lowers each
    offload block with ``in_shardings`` and reads per-device dot FLOPs
    and collective ring-volume bytes straight off the compiled (post-
    SPMD) HLO, plus a per-variable h2d factor (a replicated upload
    copies to every device; a sharded one moves each byte once).

The tuner crosses these placements with its existing policy × streams ×
fusion × donation grid (``PlanConfig.mesh_placement``), prices the
collectives against ``ici_bw`` (``roofline.analysis.offload_cost_terms``)
and records the winning placement in ``plan.meta["mesh"]`` — which
``execute()`` re-applies on any placement-capable backend.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.backend import Event, JaxDeviceBackend, register_backend
from repro.distributed.sharding import make_rules, spec_for_axes

__all__ = [
    "MeshBackend", "DEFAULT_PLACEMENTS", "auto_mesh_shape",
    "canonical_placement", "placement_specs", "mesh_cost_terms",
]

# the tuner's placement axis: replicate everywhere / FSDP-shard dim 0
# over "data" / TP-shard the last dim over "model"
DEFAULT_PLACEMENTS = ("replicate", "fsdp", "tp")


def auto_mesh_shape(n_devices: int,
                    axes: Tuple[str, str] = ("data", "model")
                    ) -> Tuple[int, int]:
    """(data, model) shape for ``n_devices``: model = largest of (4, 2, 1)
    dividing it, data = the rest.  8 → (2, 4); 1 → (1, 1)."""
    model = next(m for m in (4, 2, 1) if n_devices % m == 0)
    return (n_devices // model, model)


def canonical_placement(placement: Any) -> Tuple[Tuple[str, tuple], ...]:
    """Normalize a placement (dict / item-iterable, entries possibly
    JSON-round-tripped lists) to a hashable, sorted
    ``((var, (entry, ...)), ...)`` tuple — the identity ``MeshBackend``
    memoizes twins and keys compiled-plan caches on."""
    if not placement:
        return ()
    items = placement.items() if hasattr(placement, "items") else placement
    out = []
    for var, entries in sorted(items):
        ent = tuple(tuple(e) if isinstance(e, (list, tuple)) else e
                    for e in (entries or ()))
        out.append((str(var), ent))
    return tuple(out)


class MeshBackend(JaxDeviceBackend):
    """JAX SPMD backend over a device mesh with per-variable placements."""

    name = "mesh"

    def __init__(self, device=None, *, mesh=None, shape=None,
                 axes: Tuple[str, ...] = ("data", "model"),
                 n_streams: int = 2, donate: bool = True,
                 placement: Any = ()):
        super().__init__(device, n_streams=n_streams, donate=donate)
        from jax.sharding import Mesh
        if mesh is None:
            devs = self._jax.devices()
            if shape is None:
                shape = auto_mesh_shape(len(devs), tuple(axes))
            n = int(np.prod(shape))
            mesh = Mesh(np.asarray(devs[:n]).reshape(shape), tuple(axes))
        self.mesh = mesh
        key = canonical_placement(placement)
        self.placement: Dict[str, tuple] = dict(key)
        self.placement_key = key
        # (placement_key, n_streams, donate) -> twin; shared by the whole
        # family so with_placement of a variant of a twin never rebuilds
        self._placement_twins: Dict[Any, "MeshBackend"] = {
            (key, n_streams, donate): self}

    # -- identity ----------------------------------------------------------
    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def mesh_desc(self) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
        return (tuple(self.mesh.devices.shape), tuple(self.mesh.axis_names))

    @property
    def mesh_key(self) -> str:
        """Mesh identity for tunecache fingerprints (shape + axes only:
        the placement is a per-candidate knob, fingerprinted through the
        tuning grid, not a property of the backend's device pool)."""
        shape, axes = self.mesh_desc
        return "x".join(f"{a}{s}" for a, s in zip(axes, shape))

    # -- twins -------------------------------------------------------------
    def variant(self, *, n_streams: Optional[int] = None,
                donate: Optional[bool] = None) -> "MeshBackend":
        ns = self.n_streams if n_streams is None else max(1, int(n_streams))
        dn = self.donate if donate is None else bool(donate)
        twin = self._variant_pool.get((ns, dn))
        if twin is None:
            twin = MeshBackend(device=self._device, mesh=self.mesh,
                               n_streams=ns, donate=dn,
                               placement=self.placement_key)
            twin._variant_pool = self._variant_pool
            twin._placement_twins = self._placement_twins
            self._variant_pool[(ns, dn)] = twin
            self._placement_twins.setdefault(
                (self.placement_key, ns, dn), twin)
        return twin

    def with_placement(self, placement: Any) -> "MeshBackend":
        """Twin with the given per-variable placement (memoized: same
        placement → same instance → shared jit/lowering caches)."""
        key = canonical_placement(placement)
        if key == self.placement_key:
            return self
        pool_key = (key, self.n_streams, self.donate)
        twin = self._placement_twins.get(pool_key)
        if twin is None:
            twin = MeshBackend(device=self._device, mesh=self.mesh,
                               n_streams=self.n_streams, donate=self.donate,
                               placement=key)
            twin._placement_twins = self._placement_twins
            self._placement_twins[pool_key] = twin
        return twin

    # -- transfers ---------------------------------------------------------
    def _sharding_for(self, name: Optional[str]):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.mesh,
                             PartitionSpec(*self.placement.get(name, ())))

    def upload(self, host, *, stream: int = 0, name=None):
        handle = self._jax.device_put(host, self._sharding_for(name))
        self._record(stream, Event(payload=handle))
        return handle


register_backend("mesh", MeshBackend)


# ---------------------------------------------------------------------------
# Placement policies and pricing (tuner-facing, no backend state)
# ---------------------------------------------------------------------------

def _build_mesh(mesh_desc):
    from jax import devices
    from jax.sharding import Mesh
    shape, axes = mesh_desc
    n = int(np.prod(shape))
    return Mesh(np.asarray(devices()[:n]).reshape(shape), tuple(axes))


def placement_specs(shapes: Dict[str, Any], mesh, policy: str
                    ) -> Tuple[Dict[str, tuple], List[tuple]]:
    """Per-variable PartitionSpec entries for one placement policy.

    ``shapes`` maps var → anything with ``.shape`` (the planner's
    abstract values); ``mesh`` is a Mesh / AbstractMesh.  Returns
    ``(specs, dropped)``: specs as plain entry tuples (JSON-safe once
    listified), dropped as the divisibility-guard records — every entry
    that survives the guard is jit-valid by construction."""
    rules = make_rules(mesh, "train")
    specs: Dict[str, tuple] = {}
    for var in sorted(shapes):
        shape = tuple(np.shape(shapes[var]) if not hasattr(shapes[var],
                                                           "shape")
                      else shapes[var].shape)
        nd = len(shape)
        if policy == "replicate" or nd == 0:
            specs[var] = ()
            continue
        if policy == "fsdp":
            axes = ("embed",) + (None,) * (nd - 1)
        elif policy == "tp":
            axes = (None,) * (nd - 1) + ("ffn",)
        else:
            raise ValueError(f"unknown placement policy {policy!r}; have "
                             f"{DEFAULT_PLACEMENTS}")
        spec = spec_for_axes(rules, shape, axes, context=var)
        specs[var] = tuple(spec)
    return specs, list(rules.dropped)


def _shard_factor(mesh_shape: Dict[str, int], entries) -> int:
    """Number of distinct shards an entry tuple splits an array into."""
    s = 1
    for e in entries or ():
        if e is None:
            continue
        for a in (e if isinstance(e, (list, tuple)) else (e,)):
            s *= mesh_shape[a]
    return s


def mesh_cost_terms(program, shapes: Dict[str, Any], backend: MeshBackend,
                    specs: Dict[str, tuple]) -> Dict[str, Any]:
    """Price one placement for the tuner's cost model, without running it.

    Lowers every non-kernel offload block with ``in_shardings`` per
    ``specs`` and reads off the compiled per-device HLO:

    * ``flops_by_block``  — per-device dot FLOPs (GSPMD partitioned the
      dots, so a tp-sharded matmul reports 1/n of the math per chip);
    * ``coll_by_block``   — ring-volume wire bytes of the collectives
      GSPMD inserted (``roofline.analysis.collective_bytes``);
    * ``h2d_factor``      — per-variable PCIe multiplier: a replicated
      upload copies the host bytes to all n devices, a fully sharded one
      moves each byte once (n / shard_count in general).

    Kernel-tagged blocks keep their analytic per-variant roofline pricing
    (they are not sharded) and are skipped here."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.roofline.analysis import (collective_bytes, dot_flops,
                                         parse_hlo)
    mesh = backend.mesh
    n_dev = backend.n_devices
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    flops_by_block: Dict[int, float] = {}
    coll_by_block: Dict[int, float] = {}
    for blk in program.offload_blocks():
        if blk.kernel:
            continue
        reads = tuple(blk.reads)
        fn = blk.fn
        writes = tuple(blk.writes)

        def wrapped(*arrays, _fn=fn, _reads=reads, _writes=writes):
            out = _fn(jax.numpy, **dict(zip(_reads, arrays)))
            return tuple(out[w] for w in _writes)

        avals = [jax.ShapeDtypeStruct(shapes[v].shape, shapes[v].dtype)
                 for v in reads]
        in_sh = [NamedSharding(mesh, PartitionSpec(*specs.get(v, ())))
                 for v in reads]
        txt = (jax.jit(wrapped, in_shardings=in_sh)
               .lower(*avals).compile().as_text())
        mod = parse_hlo(txt)
        flops_by_block[blk.idx] = dot_flops(mod)
        coll_by_block[blk.idx] = sum(
            v["bytes"] for v in collective_bytes(mod).values())
    h2d_factor = {v: n_dev / _shard_factor(mesh_shape, e)
                  for v, e in specs.items()}
    return {
        "specs": specs,
        "flops_by_block": flops_by_block,
        "coll_by_block": coll_by_block,
        "h2d_factor": h2d_factor,
        "n_devices": n_dev,
    }
