"""Distribution: sharding rules, collectives, pipeline parallelism."""
from .sharding import (MeshPolicy, ShardingRules, batch_axes, batch_specs,
                       cache_shardings, make_rules, spec_for_axes,
                       tree_shardings)

__all__ = ["MeshPolicy", "ShardingRules", "batch_axes", "batch_specs",
           "cache_shardings", "make_rules", "spec_for_axes",
           "tree_shardings"]
