"""Pipeline parallelism over the pod axis (GPipe schedule via shard_map +
collective_permute).

Alternative use of the multi-pod mesh: instead of cross-pod DP, the two
pods hold disjoint layer ranges and microbatches stream through
(F-then-B GPipe; bubble = (P-1)/(M+P-1)).  Implemented as a shard_map over
the "pod" axis where every stage runs the SAME scanned layer body over its
own parameter shard, and boundary activations move by ``ppermute``.

The forward pipeline below is complete and dry-run-lowerable; training
composes it with jax.grad through the shard_map (linear collectives
transpose automatically: ppermute → reverse ppermute).  It is exercised by
tests/test_pipeline.py on an 8-device mesh and by the
``--variant pipeline`` dry-run config.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_forward"]


def pipeline_forward(mesh, layer_fn: Callable[[Any, jax.Array], jax.Array],
                     n_microbatches: int, stage_axis: str = "pod"):
    """Build fn(stage_params, x) running a GPipe forward.

    stage_params: pytree whose leaves have a leading [n_stages] dim sharded
      over ``stage_axis`` (each stage sees its own slice inside shard_map).
    x: (B, ...) global batch, split into ``n_microbatches`` along B.
    layer_fn(stage_params_slice, mb) -> mb.
    """
    n_stages = mesh.shape[stage_axis]

    def staged(params_local, x_local):
        # params_local leaves: [1, ...] (this stage's slice)
        p = jax.tree.map(lambda t: t[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        B = x_local.shape[0]
        mb_size = B // n_microbatches
        mbs = x_local.reshape((n_microbatches, mb_size) + x_local.shape[1:])

        n_ticks = n_microbatches + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            outputs, inflight = carry
            # microbatch entering stage 0 at tick t (zeros once drained)
            mb_idx = jnp.clip(t, 0, n_microbatches - 1)
            feed = mbs[mb_idx] * (t < n_microbatches).astype(mbs.dtype)
            incoming = jnp.where(stage == 0, feed, inflight)
            out = layer_fn(p, incoming)
            # hand activations to the next stage
            inflight_next = jax.lax.ppermute(out, stage_axis, fwd_perm)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            valid = (t >= n_stages - 1)
            outputs = outputs.at[emit_idx].set(
                jnp.where(valid, out, outputs[emit_idx]))
            return (outputs, inflight_next), None

        out0 = jnp.zeros_like(mbs)
        inflight0 = jnp.zeros_like(mbs[0])
        (outputs, _), _ = jax.lax.scan(
            tick, (out0, inflight0), jnp.arange(n_ticks))
        # only the last stage holds real outputs; a masked psum broadcasts
        # them (ppermute cannot fan out one source to many destinations)
        outputs = jax.lax.psum(
            outputs * (stage == n_stages - 1).astype(outputs.dtype),
            stage_axis)
        return outputs.reshape((B,) + x_local.shape[1:])

    def run(stage_params, x):
        in_specs = (jax.tree.map(lambda _: P(stage_axis), stage_params),
                    P())
        return shard_map(staged, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)(stage_params, x)

    return run
