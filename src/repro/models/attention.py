"""Attention: GQA with RoPE, blockwise online-softmax (flash algorithm in
jnp — no S×S materialization, so 32k prefill fits), sliding-window local
attention, and sequence-shardable decode against a KV cache.

On real TPU the blockwise path is replaced by the Pallas flash kernel
(``repro.kernels.flash_attention``) via ``use_pallas=True``; both are
validated against the same oracle in tests.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import P, Policy, apply_rope, rms_norm

__all__ = ["attn_spec", "attn_apply", "attn_decode", "init_kv_cache",
           "blockwise_attention", "decode_attention"]

NEG_INF = -1e30


def attn_spec(cfg, prefix_shape=(), prefix_names=()) -> Dict[str, P]:
    pa, pn = tuple(prefix_shape), tuple(prefix_names)
    d, q = cfg.d_model, cfg.n_heads * cfg.d_head
    spec = {
        "w_q": P(pa + (d, cfg.n_heads, cfg.d_head),
                 pn + ("embed", "heads", "head_dim")),
        "w_k": P(pa + (d, cfg.n_kv_heads, cfg.d_head),
                 pn + ("embed", "kv_heads", "head_dim")),
        "w_v": P(pa + (d, cfg.n_kv_heads, cfg.d_head),
                 pn + ("embed", "kv_heads", "head_dim")),
        "w_o": P(pa + (cfg.n_heads, cfg.d_head, d),
                 pn + ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["b_q"] = P(pa + (cfg.n_heads, cfg.d_head),
                        pn + ("heads", "head_dim"), init="zeros")
        spec["b_k"] = P(pa + (cfg.n_kv_heads, cfg.d_head),
                        pn + ("kv_heads", "head_dim"), init="zeros")
        spec["b_v"] = P(pa + (cfg.n_kv_heads, cfg.d_head),
                        pn + ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        spec["qnorm"] = P(pa + (cfg.d_head,), pn + ("head_dim",),
                          init="ones")
        spec["knorm"] = P(pa + (cfg.d_head,), pn + ("head_dim",),
                          init="ones")
    return spec


def _project_qkv(params, x, cfg, positions, policy=None):
    def hint(w, kind):
        if policy is None:
            return w
        return policy.acts(w, kind)
    q = jnp.einsum("bsd,dhk->bshk", x, hint(params["w_q"], "w_attn_q"))
    k = jnp.einsum("bsd,dhk->bshk", x, hint(params["w_k"], "w_attn_kv"))
    v = jnp.einsum("bsd,dhk->bshk", x, hint(params["w_v"], "w_attn_kv"))
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    if "qnorm" in params:
        q = rms_norm(q, params["qnorm"])
        k = rms_norm(k, params["knorm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        q_offset: int = 0):
    """Flash-style attention without S×S materialization.

    q: (B, S, K, G, D) — G query heads per KV head; k, v: (B, T, K, D).
    Online softmax over KV chunks (inner scan), mapped over Q chunks.
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, q_chunk, T, kv_chunk)
    nq, nk = S // q_chunk, T // kv_chunk
    scale = 1.0 / (D ** 0.5)
    qf = (q * scale).reshape(B, nq, q_chunk, K, G, D)
    kf = k.reshape(B, nk, kv_chunk, K, D)
    vf = v.reshape(B, nk, kv_chunk, K, D)
    out_dtype = q.dtype

    def one_q_block(args):
        qi, qblk = args            # qblk: (B, qc, K, G, D)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv):
            o, m, lse = carry
            ki, kblk, vblk = kv
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,btkd->bkgqt",
                           qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32))
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = lse * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p,
                            vblk.astype(jnp.float32))
            o_new = o * corr[..., None] + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, K, G, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        (o, m, lse), _ = jax.lax.scan(
            kv_step, (o0, m0, l0),
            (jnp.arange(nk), jnp.moveaxis(kf, 1, 0),
             jnp.moveaxis(vf, 1, 0)))
        o = o / jnp.maximum(lse[..., None], 1e-30)
        return jnp.moveaxis(o, 3, 1)           # (B, qc, K, G, D)

    o = jax.lax.map(one_q_block,
                    (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, K, G, D)
    return o.astype(out_dtype)


def attn_apply(params, x, cfg, positions, *,
               policy: Optional[Policy] = None, window: int = 0,
               use_pallas: bool = False):
    """Training / prefill self-attention.  x: (B, S, d_model)."""
    B, S, _ = x.shape
    K, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(params, x, cfg, positions, policy=policy)
    q = q.reshape(B, S, K, G, cfg.d_head)
    if policy is not None:
        q = policy.acts(q, "q5")
        k = policy.acts(k, "kv4")
        v = policy.acts(v, "kv4")
    if use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        o = blockwise_attention(q, k, v, causal=True, window=window)
    o = o.reshape(B, S, cfg.n_heads, cfg.d_head)
    w_o = params["w_o"] if policy is None else policy.acts(
        params["w_o"], "w_attn_out")
    return jnp.einsum("bshk,hkd->bsd", o, w_o)


# ---------------------------------------------------------------------------
# Decode path: single-token step against a KV cache.
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_seq: int, n_attn_layers: int,
                  dtype=jnp.bfloat16, window: int = 0,
                  quant: bool = False):
    """Full cache (B, T, K, D) per layer — or ring buffer of ``window``.

    ``quant``: int8 storage with per-(token, head) scales (KIVI-style) —
    halves the decode step's dominant HBM term (§Perf iteration 'kvq8');
    dequantization happens inside the attention fp32 einsum."""
    T = min(max_seq, window) if window else max_seq
    shape = (n_attn_layers, batch, T, cfg.n_kv_heads, cfg.d_head)
    if quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.float32),
            "v_scale": jnp.zeros(shape[:-1], jnp.float32),
            "pos": jnp.zeros((n_attn_layers, batch, T), jnp.int32) - 1,
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((n_attn_layers, batch, T), jnp.int32) - 1,
    }


def _quantize_kv(x):
    """x: (B, K, D) one token → (int8, scale (B, K))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention(q, k_cache, v_cache, cache_pos, pos, *, window: int = 0):
    """q: (B, 1, K, G, D); caches: (B, T, K, D); cache_pos: (B, T) absolute
    positions stored in each cache slot (-1 = empty); pos: (B,) current
    position.  Full-length masked attention — T is static, the validity
    mask handles both causal order and (for ring buffers) the window."""
    B, _, K, G, D = q.shape
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bqkgd,btkd->bkgqt", (q * scale).astype(jnp.float32),
                   k_cache.astype(jnp.float32))      # (B,K,G,1,T)
    valid = (cache_pos >= 0) & (cache_pos <= pos[:, None])
    if window:
        valid &= cache_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)


def attn_decode(params, x, cfg, cache, pos, *,
                policy: Optional[Policy] = None, window: int = 0):
    """One decode step.  x: (B, 1, d_model); pos: (B,) int32 current index.
    cache: dict(k, v[, k_scale, v_scale], pos) for THIS layer.
    Returns (out (B,1,d), new_cache)."""
    B = x.shape[0]
    K, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(params, x, cfg, pos[:, None])
    T = cache["k"].shape[1]
    quant = "k_scale" in cache
    slot = (pos % T) if window else pos             # ring buffer for local
    b_idx = jnp.arange(B)
    new_cache = {}
    if quant:
        kq, ks = _quantize_kv(k[:, 0])
        vq, vs = _quantize_kv(v[:, 0])
        new_k = cache["k"].at[b_idx, slot].set(kq)
        new_v = cache["v"].at[b_idx, slot].set(vq)
        new_ks = cache["k_scale"].at[b_idx, slot].set(ks)
        new_vs = cache["v_scale"].at[b_idx, slot].set(vs)
        new_cache["k_scale"], new_cache["v_scale"] = new_ks, new_vs
        att_k = new_k.astype(jnp.float32) * new_ks[..., None]
        att_v = new_v.astype(jnp.float32) * new_vs[..., None]
    else:
        new_k = cache["k"].at[b_idx, slot].set(k[:, 0])
        new_v = cache["v"].at[b_idx, slot].set(v[:, 0])
        att_k, att_v = new_k, new_v
    new_cpos = cache["pos"].at[b_idx, slot].set(pos)
    if policy is not None:
        new_k = policy.acts(new_k, "kvcache")
        new_v = policy.acts(new_v, "kvcache")
        att_k = policy.acts(att_k, "kvcache")
        att_v = policy.acts(att_v, "kvcache")
    q = q.reshape(B, 1, K, G, cfg.d_head)
    o = decode_attention(q, att_k, att_v, new_cpos, pos, window=window)
    o = o.reshape(B, 1, cfg.n_heads, cfg.d_head)
    out = jnp.einsum("bshk,hkd->bsd", o, params["w_o"])
    new_cache.update({"k": new_k, "v": new_v, "pos": new_cpos})
    return out, new_cache
