"""Griffin recurrent block: temporal conv + RG-LRU gated linear recurrence
[arXiv:2402.19427].

The RG-LRU diagonal recurrence
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
    a_t = exp(-c · softplus(Λ) ⊙ σ(W_a x_t))
is associative → training uses ``jax.lax.associative_scan`` (parallel,
O(log T) depth); decode is a single-step update carrying (h, conv window).
The full Griffin block is the gated variant:
    out = W_out ( GeLU(W_gate x) ⊙ RG-LRU(conv1d(W_x x)) ).
On real TPU the scan is the Pallas kernel ``repro.kernels.rglru_scan``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .layers import P, Policy

__all__ = ["rglru_spec", "rglru_apply", "rglru_decode", "init_rglru_cache",
           "rglru_scan_ref", "RGLRU_C"]

RGLRU_C = 8.0


def rglru_spec(cfg, prefix_shape=(), prefix_names=()) -> Dict[str, Any]:
    pa, pn = tuple(prefix_shape), tuple(prefix_names)
    d = cfg.d_model
    w = cfg.rglru_conv_width
    return {
        "w_x":    P(pa + (d, d), pn + ("embed", "rnn")),
        "w_gate": P(pa + (d, d), pn + ("embed", "rnn")),
        "w_out":  P(pa + (d, d), pn + ("rnn", "embed")),
        "conv_w": P(pa + (w, d), pn + (None, "rnn"), init="zeros"),
        "conv_b": P(pa + (d,), pn + ("rnn",), init="zeros"),
        "w_a":    P(pa + (d, d), pn + ("embed", "rnn")),
        "w_i":    P(pa + (d, d), pn + ("embed", "rnn")),
        "lam":    P(pa + (d,), pn + ("rnn",), init="ones"),
    }


def _gates(params, u, x):
    """u: conv output (..., d) drives the recurrence input; x: raw block
    input drives the gates (a_t, i_t)."""
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(params["lam"]).astype(jnp.float32)
                * jax.nn.sigmoid(x @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(x @ params["w_i"]).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * \
        (i * u.astype(jnp.float32))
    return a, b


def rglru_scan_ref(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t along axis 1 (time).  a, b: (B, T, D)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _conv1d(params, x, width: int, state=None):
    """Causal depthwise temporal conv.  x: (B, T, d).  ``state``: (B, w-1, d)
    previous inputs for decode continuity."""
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i]
              for i in range(width))
    return out + params["conv_b"], xp[:, -(width - 1):]


def rglru_apply(params, x, cfg, *, policy: Optional[Policy] = None,
                use_pallas: bool = False):
    """Training/prefill.  x: (B, T, d) -> (B, T, d)."""
    u = x @ params["w_x"]
    u, _ = _conv1d(params, u, cfg.rglru_conv_width)
    a, b = _gates(params, u, x)
    if use_pallas:
        from repro.kernels import ops as kops
        h = kops.rglru_scan(a, b)
    else:
        h = rglru_scan_ref(a, b)
    h = h.astype(x.dtype)
    if policy is not None:
        h = policy.acts(h, "rnn_hidden")
    gate = jax.nn.gelu(x @ params["w_gate"])
    return (gate * h) @ params["w_out"]


def init_rglru_cache(cfg, n_layers: int, batch: int, dtype=jnp.bfloat16):
    d, w = cfg.d_model, cfg.rglru_conv_width
    return {
        "h": jnp.zeros((n_layers, batch, d), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, w - 1, d), dtype),
    }


def rglru_decode(params, x, cfg, cache, *,
                 policy: Optional[Policy] = None):
    """One step.  x: (B, 1, d); cache: dict(h (B,d), conv (B,w-1,d))."""
    u = x @ params["w_x"]
    u, conv_state = _conv1d(params, u, cfg.rglru_conv_width,
                            state=cache["conv"])
    a, b = _gates(params, u, x)
    h = a[:, 0] * cache["h"] + b[:, 0]                 # (B, d) fp32
    gate = jax.nn.gelu(x @ params["w_gate"])
    out = (gate * h[:, None].astype(x.dtype)) @ params["w_out"]
    return out, {"h": h, "conv": conv_state}
