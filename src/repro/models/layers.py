"""Shared NN layers: norms, rope, FFN variants, losses, param specs.

Params are plain nested dicts.  Every leaf is created from a ``P`` spec that
carries its *logical axes* — the distribution layer maps logical axes to
mesh axes (see ``repro.distributed.sharding``), so model code never mentions
the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "P", "init_tree", "abstract_tree", "axes_tree", "rms_norm",
    "apply_rope", "rope_freqs", "ffn_apply", "ffn_spec",
    "cross_entropy", "Policy",
]


@dataclasses.dataclass(frozen=True)
class P:
    """Param spec leaf: shape + logical axes + initializer."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, P)


def init_tree(spec: Dict[str, Any], key: jax.Array, dtype) -> Dict[str, Any]:
    """Materialize a spec tree into concrete params."""
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        if leaf.init == "zeros":
            arr = jnp.zeros(leaf.shape, dtype)
        elif leaf.init == "ones":
            arr = jnp.ones(leaf.shape, dtype)
        else:
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            std = leaf.scale / np.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(k, leaf.shape, jnp.float32)
                   * std).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_tree(spec: Dict[str, Any], dtype) -> Dict[str, Any]:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        spec, is_leaf=_is_spec)


def axes_tree(spec: Dict[str, Any]) -> Dict[str, Any]:
    """Logical-axis tree parallel to the params."""
    return jax.tree.map(lambda p: p.axes, spec, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# Activation-sharding policy: model code calls policy.acts(x, kind) at the
# few places GSPMD needs a hint; a None policy is the identity (CPU tests).
# ---------------------------------------------------------------------------

class Policy:
    def acts(self, x, kind: str):
        return x


def _acts(policy: Optional[Policy], x, kind: str):
    return policy.acts(x, kind) if policy is not None else x


# ---------------------------------------------------------------------------
# Norms / RoPE / FFN / losses
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    if x.dtype == jnp.float32:
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(var + eps) * weight
    # bf16 path: contract in bf16 with fp32 ACCUMULATION (the MXU-native
    # mixed-precision dot) instead of materializing an fp32 copy of x —
    # under GSPMD a D-sharded residual then reduces via partial sums +
    # a (B, S) all-reduce rather than all-gathering an fp32 (B, S, D)
    # (§Perf: this halved the dense-train collective traffic)
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = ss / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)
    return (x * inv[..., None].astype(x.dtype)) * weight


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2,
                                       dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def ffn_spec(d_model: int, d_ff: int, activation: str,
             prefix_axes: Tuple[int, ...] = (),
             prefix_names: Tuple[str, ...] = ()) -> Dict[str, P]:
    """FFN params; ``prefix_axes/names`` prepend stacking dims (layers or
    experts)."""
    pa, pn = tuple(prefix_axes), tuple(prefix_names)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": P(pa + (d_model, d_ff), pn + ("embed", "ffn")),
            "w_up":   P(pa + (d_model, d_ff), pn + ("embed", "ffn")),
            "w_down": P(pa + (d_ff, d_model), pn + ("ffn", "embed")),
        }
    # sq_relu (Primer / Nemotron-4) and friends: two matrices
    return {
        "w_up":   P(pa + (d_model, d_ff), pn + ("embed", "ffn")),
        "w_down": P(pa + (d_ff, d_model), pn + ("ffn", "embed")),
    }


def ffn_apply(params, x, activation: str, policy: Optional[Policy] = None):
    w_up = _acts(policy, params["w_up"], "w_ffn_in")
    w_down = _acts(policy, params["w_down"], "w_ffn_out")
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        w_gate = _acts(policy, params["w_gate"], "w_ffn_in")
        h = act(x @ w_gate) * (x @ w_up)
    elif activation == "sq_relu":
        h = jnp.square(jax.nn.relu(x @ w_up))
    else:
        raise ValueError(activation)
    h = _acts(policy, h, "ffn_hidden")
    return h @ w_down


def cross_entropy(logits, labels, ignore_label: int = -1):
    """Mean CE in fp32; labels == ignore_label are masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore_label).astype(jnp.float32)
    loss = (logz - gold) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
