"""RWKV-6 "Finch" block [arXiv:2404.05892]: attention-free time-mix with
data-dependent per-channel decay + squared-ReLU channel-mix.

Per head (head size hs), with state S ∈ R^{hs×hs}:
    o_t[j] = Σ_i r_t[i] · (S_{t-1}[i,j] + u[i]·k_t[i]·v_t[j])
    S_t    = diag(w_t) · S_{t-1} + k_t ⊗ v_t
where w_t = exp(-exp(w0 + lora_w(x̃_t))) is the data-dependent decay (the
paper's headline novelty over RWKV-5) and the x̃ inputs are ddlerp token
shifts.  Training uses a time scan (Pallas chunked kernel on real TPU:
``repro.kernels.wkv6``); decode carries (S, x_prev) per layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .layers import P, Policy, rms_norm

__all__ = ["rwkv6_spec", "rwkv6_time_mix", "rwkv6_channel_mix",
           "init_rwkv_cache", "wkv6_scan_ref"]

LORA_R = 32
_MIX = ("w", "k", "v", "r", "g")


def rwkv6_spec(cfg, prefix_shape=(), prefix_names=()) -> Dict[str, Any]:
    pa, pn = tuple(prefix_shape), tuple(prefix_names)
    d, f = cfg.d_model, cfg.d_ff
    tm: Dict[str, Any] = {
        "mu_x": P(pa + (d,), pn + ("embed",), init="zeros"),
        "w0":   P(pa + (d,), pn + ("embed",), init="zeros"),
        "u":    P(pa + (d,), pn + ("embed",), init="zeros"),
        "ln_x": P(pa + (d,), pn + ("embed",), init="ones"),
        "w_out": P(pa + (d, d), pn + ("heads", "embed")),
    }
    for z in _MIX:
        tm[f"mu_{z}"] = P(pa + (d,), pn + ("embed",), init="zeros")
        tm[f"lora_a_{z}"] = P(pa + (d, LORA_R), pn + ("embed", None))
        tm[f"lora_b_{z}"] = P(pa + (LORA_R, d), pn + (None, "embed"),
                              init="zeros")
        if z != "w":
            tm[f"w_{z}"] = P(pa + (d, d), pn + ("embed", "heads"))
    cm = {
        "mu_k": P(pa + (d,), pn + ("embed",), init="zeros"),
        "mu_r": P(pa + (d,), pn + ("embed",), init="zeros"),
        "w_k": P(pa + (d, f), pn + ("embed", "ffn")),
        "w_v": P(pa + (f, d), pn + ("ffn", "embed")),
        "w_r": P(pa + (d, d), pn + ("embed", "embed_out")),
    }
    return {"tm": tm, "cm": cm}


def _token_shift(x, x_prev):
    """x: (B, T, d); x_prev: (B, d) last token of the previous segment.
    Returns the previous-token tensor aligned with x."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(p, x, sx, z: str):
    """Data-dependent lerp (RWKV-6): mix x with shifted sx."""
    xx = sx - x
    inner = x + xx * p["mu_x"]
    lora = jnp.tanh(inner @ p[f"lora_a_{z}"]) @ p[f"lora_b_{z}"]
    return x + xx * (p[f"mu_{z}"] + lora)


def wkv6_scan_ref(r, k, v, w, u):
    """Sequential oracle.  r,k,v,w: (B, T, H, hs); u: (H, hs) bonus.
    Returns (o (B,T,H,hs), final state (B,H,hs,hs))."""
    B, T, H, hs = r.shape
    s0 = jnp.zeros((B, H, hs, hs), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp          # (B, H, hs)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,hs,hs)
        o = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, o

    rr, kk, vv, ww = (jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                      for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s0, (rr, kk, vv, ww))
    return jnp.moveaxis(o, 0, 1), s


def rwkv6_time_mix(p, x, cfg, *, x_prev=None, state=None,
                   policy: Optional[Policy] = None,
                   use_pallas: bool = False):
    """x: (B, T, d).  Returns (out, (new_x_prev, new_state))."""
    B, T, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    sx = _token_shift(x, x_prev)

    xw = _ddlerp(p, x, sx, "w")
    xk = _ddlerp(p, x, sx, "k")
    xv = _ddlerp(p, x, sx, "v")
    xr = _ddlerp(p, x, sx, "r")
    xg = _ddlerp(p, x, sx, "g")

    r = (xr @ p["w_r"]).reshape(B, T, H, hs)
    k = (xk @ p["w_k"]).reshape(B, T, H, hs)
    v = (xv @ p["w_v"]).reshape(B, T, H, hs)
    g = jax.nn.silu(xg @ p["w_g"])
    dec = p["w0"] + jnp.tanh(xw @ p["lora_a_w"]) @ p["lora_b_w"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, T, H, hs)
    u = p["u"].reshape(H, hs)

    if state is not None:
        # decode / segment continuation: fold initial state in via the scan
        o, new_state = _wkv_with_state(r, k, v, w, u, state)
    elif use_pallas:
        from repro.kernels import ops as kops
        o, new_state = kops.wkv6(r, k, v, w, u)
    else:
        o, new_state = wkv6_scan_ref(r, k, v, w, u)

    o = o.reshape(B, T, d).astype(x.dtype)
    o = rms_norm(o.reshape(B, T, H, hs), jnp.ones((hs,), x.dtype)
                 ).reshape(B, T, d) * p["ln_x"]
    if policy is not None:
        o = policy.acts(o, "embeds")
    out = (o * g) @ p["w_out"]
    return out, (x[:, -1], new_state)


def _wkv_with_state(r, k, v, w, u, s0):
    B, T, H, hs = r.shape

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, o

    rr, kk, vv, ww = (jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                      for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s0.astype(jnp.float32), (rr, kk, vv, ww))
    return jnp.moveaxis(o, 0, 1), s


def rwkv6_channel_mix(p, x, cfg, *, x_prev=None):
    """Squared-ReLU channel mix with simple token-shift lerp."""
    B, T, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    sx = _token_shift(x, x_prev)
    xx = sx - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"]), x[:, -1]


def init_rwkv_cache(cfg, n_layers: int, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    return {
        "tm_x": jnp.zeros((n_layers, batch, d), dtype),
        "cm_x": jnp.zeros((n_layers, batch, d), dtype),
        "state": jnp.zeros((n_layers, batch, H, hs, hs), jnp.float32),
    }


