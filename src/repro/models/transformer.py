"""Decoder assembly for all assigned architectures.

Layer stacking uses ``lax.scan`` over stacked parameters (one traced layer
body regardless of depth → small HLO, fast multi-pod compiles) with
per-layer ``jax.checkpoint`` remat.  The Griffin hybrid (R,R,A pattern)
scans over *periods* — a period body applies two RG-LRU layers and one
local-attention layer from separate stacked trees, so no parameter padding
is wasted (26 layers = 8 periods + 2 tail recurrent layers).

Three entry points:
  * ``loss``        — training objective (chunked CE; never materializes
                      (B, S, vocab)),
  * ``prefill``     — forward pass that also builds the serving cache
                      (KV / ring-buffer / recurrent state per layer kind),
  * ``decode_step`` — one-token step against the cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_decode, attn_spec, init_kv_cache
from .layers import (P, Policy, abstract_tree, axes_tree, cross_entropy,
                     ffn_apply, ffn_spec, init_tree, rms_norm)
from .moe import moe_apply, moe_spec
from .rglru import init_rglru_cache, rglru_decode, rglru_spec
from .rwkv6 import (init_rwkv_cache, rwkv6_channel_mix, rwkv6_spec,
                    rwkv6_time_mix)

__all__ = ["Transformer", "model_spec"]

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _attn_layer_spec(cfg, n: int) -> Dict[str, Any]:
    spec = {
        "ln1": P((n, cfg.d_model), ("layers", "embed"), init="ones"),
        "ln2": P((n, cfg.d_model), ("layers", "embed"), init="ones"),
        "attn": attn_spec(cfg, (n,), ("layers",)),
    }
    if cfg.is_moe:
        spec["moe"] = moe_spec(cfg, (n,), ("layers",))
    else:
        spec["ffn"] = ffn_spec(cfg.d_model, cfg.d_ff, cfg.activation,
                               (n,), ("layers",))
    return spec


def _rec_layer_spec(cfg, shape_prefix, name_prefix) -> Dict[str, Any]:
    pa, pn = tuple(shape_prefix), tuple(name_prefix)
    return {
        "ln1": P(pa + (cfg.d_model,), pn + ("embed",), init="ones"),
        "ln2": P(pa + (cfg.d_model,), pn + ("embed",), init="ones"),
        "rglru": rglru_spec(cfg, pa, pn),
        "ffn": ffn_spec(cfg.d_model, cfg.d_ff, cfg.activation, pa, pn),
    }


def _rwkv_layer_spec(cfg, n: int) -> Dict[str, Any]:
    return {
        "ln1": P((n, cfg.d_model), ("layers", "embed"), init="ones"),
        "ln2": P((n, cfg.d_model), ("layers", "embed"), init="ones"),
        "rwkv": rwkv6_spec(cfg, (n,), ("layers",)),
    }


def model_spec(cfg) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    spec: Dict[str, Any] = {
        "final_norm": P((d,), ("embed",), init="ones"),
    }
    if cfg.input_embeds:
        spec["in_proj"] = P((d, d), ("embed", "embed_out"))
    else:
        spec["embed"] = P((v, d), ("vocab", "embed"))
    n_out = max(cfg.n_codebooks, 1) * v
    spec["head"] = P((d, n_out), ("embed", "vocab"))

    if cfg.layer_pattern == "rwkv":
        spec["layers"] = _rwkv_layer_spec(cfg, cfg.n_layers)
    elif cfg.layer_pattern == "griffin":
        n_periods, tail = divmod(cfg.n_layers, 3)
        spec["periods"] = {
            "rec": _rec_layer_spec(cfg, (n_periods, 2), ("layers", None)),
            "attn": {
                "ln1": P((n_periods, d), ("layers", "embed"), init="ones"),
                "ln2": P((n_periods, d), ("layers", "embed"), init="ones"),
                "attn": attn_spec(cfg, (n_periods,), ("layers",)),
                "ffn": ffn_spec(d, cfg.d_ff, cfg.activation,
                                (n_periods,), ("layers",)),
            },
        }
        if tail:
            spec["tail"] = _rec_layer_spec(cfg, (tail,), ("layers",))
    else:
        spec["layers"] = _attn_layer_spec(cfg, cfg.n_layers)
    return spec


# ---------------------------------------------------------------------------
# Layer bodies.  Each returns (x, aux, cache_out); cache_out is {} unless
# ``collect`` (prefill) is set.
# ---------------------------------------------------------------------------

def _ring_cache_from_kv(k, v, window: int):
    """Pack the last ``window`` (roped) k/v into a ring buffer laid out by
    absolute-position % window (matching the decode-side slot rule)."""
    B, S, K, D = k.shape
    W = min(window, S)
    pos = jnp.arange(S - W, S)
    slot = pos % window if S >= window else pos
    ck = jnp.zeros((B, window, K, D), k.dtype).at[:, slot].set(k[:, -W:])
    cv = jnp.zeros((B, window, K, D), v.dtype).at[:, slot].set(v[:, -W:])
    cpos = (jnp.zeros((B, window), jnp.int32) - 1).at[:, slot].set(
        jnp.broadcast_to(pos, (B, W)))
    return {"k": ck, "v": cv, "pos": cpos}


def _full_cache_from_kv(k, v, max_seq: int):
    B, S, K, D = k.shape
    ck = jnp.zeros((B, max_seq, K, D), k.dtype).at[:, :S].set(k)
    cv = jnp.zeros((B, max_seq, K, D), v.dtype).at[:, :S].set(v)
    cpos = (jnp.zeros((B, max_seq), jnp.int32) - 1).at[:, :S].set(
        jnp.arange(S))
    return {"k": ck, "v": cv, "pos": cpos}


def _attn_block(lp, x, cfg, positions, policy, window, use_pallas,
                collect=False, max_seq=0, moe_ep=False):
    xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if policy is not None:
        xn = policy.acts(xn, "block_in")
    if collect:
        from .attention import _project_qkv, blockwise_attention
        B, S, _ = xn.shape
        K, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
        q, k, v = _project_qkv(lp["attn"], xn, cfg, positions)
        qr = q.reshape(B, S, K, G, cfg.d_head)
        o = blockwise_attention(qr, k, v, causal=True, window=window)
        o = o.reshape(B, S, cfg.n_heads, cfg.d_head)
        attn_out = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["w_o"])
        cache = (_ring_cache_from_kv(k, v, window) if window
                 else _full_cache_from_kv(k, v, max_seq))
    else:
        attn_out = attn_apply(lp["attn"], xn, cfg, positions, policy=policy,
                              window=window, use_pallas=use_pallas)
        cache = {}
    h = x + attn_out
    hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if policy is not None:
        hn = policy.acts(hn, "block_in")
    if cfg.is_moe:
        if moe_ep and policy is not None and hasattr(policy, "rules"):
            from .moe import moe_apply_ep
            f, aux = moe_apply_ep(lp["moe"], hn, cfg, policy.rules.mesh,
                                  policy=policy)
        else:
            f, aux = moe_apply(lp["moe"], hn, cfg, policy=policy)
    else:
        f, aux = ffn_apply(lp["ffn"], hn, cfg.activation,
                           policy=policy), 0.0
    out = h + f
    if policy is not None:
        out = policy.acts(out, "embeds")
    return out, aux, cache


def _rec_block(lp, x, cfg, policy, use_pallas, collect=False):
    from .rglru import _conv1d, _gates, rglru_scan_ref
    xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
    rp = lp["rglru"]
    u = xn @ rp["w_x"]
    u, conv_state = _conv1d(rp, u, cfg.rglru_conv_width)
    a, b = _gates(rp, u, xn)
    if use_pallas and not collect:
        from repro.kernels import ops as kops
        hseq = kops.rglru_scan(a, b)
    else:
        hseq = rglru_scan_ref(a, b)
    gate = jax.nn.gelu(xn @ rp["w_gate"])
    o = (gate * hseq.astype(x.dtype)) @ rp["w_out"]
    h = x + o
    h = h + ffn_apply(lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                      cfg.activation, policy=policy)
    if policy is not None:
        h = policy.acts(h, "embeds")
    cache = ({"h": hseq[:, -1].astype(jnp.float32), "conv": conv_state}
             if collect else {})
    return h, cache


def _rwkv_block(lp, x, cfg, policy, use_pallas, collect=False):
    o, (tm_x, state) = rwkv6_time_mix(
        lp["rwkv"]["tm"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
        policy=policy, use_pallas=use_pallas and not collect)
    h = x + o
    o2, cm_x = rwkv6_channel_mix(
        lp["rwkv"]["cm"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
    out = h + o2
    if policy is not None:
        out = policy.acts(out, "embeds")
    cache = ({"tm_x": tm_x, "cm_x": cm_x, "state": state}
             if collect else {})
    return out, cache


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Transformer:
    cfg: Any
    use_pallas: bool = False
    moe_ep: bool = False   # expert-parallel shard_map MoE (train/prefill)
    kv_quant: bool = False  # int8 KV cache (decode)

    # ---- params ----------------------------------------------------------
    def spec(self):
        return model_spec(self.cfg)

    def init(self, key, dtype=None):
        dt = dtype or jnp.dtype(self.cfg.dtype)
        return init_tree(self.spec(), key, dt)

    def abstract_params(self, dtype=None):
        dt = dtype or jnp.dtype(self.cfg.dtype)
        return abstract_tree(self.spec(), dt)

    def logical_axes(self):
        return axes_tree(self.spec())

    # ---- embedding -------------------------------------------------------
    def _embed(self, params, batch, policy):
        cfg = self.cfg
        if cfg.input_embeds:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
            x = x @ params["in_proj"]
        else:
            x = params["embed"][batch["tokens"]]
        if policy is not None:
            x = policy.acts(x, "embeds")
        return x

    def _backbone(self, params, x, positions, policy, *,
                  collect=False, max_seq=0):
        """Run all layers.  Returns (hidden, aux_loss, caches)."""
        cfg = self.cfg
        use_pallas = self.use_pallas

        if cfg.layer_pattern == "rwkv":
            def body(carry, lp):
                x, aux = carry
                x, cache = _rwkv_block(lp, x, cfg, policy, use_pallas,
                                       collect)
                return (x, aux), cache
            (x, aux), caches = jax.lax.scan(
                jax.checkpoint(body), (x, 0.0), params["layers"])
            return x, aux, caches

        if cfg.layer_pattern == "griffin":
            window = cfg.local_window

            def period_body(carry, lp):
                x, aux = carry
                rec, att = lp["rec"], lp["attn"]
                rc = []
                for i in range(2):
                    x, c = _rec_block(jax.tree.map(lambda t: t[i], rec), x,
                                      cfg, policy, use_pallas, collect)
                    rc.append(c)
                x, a, ac = _attn_block(att, x, cfg, positions, policy,
                                       window, use_pallas, collect, max_seq)
                cache = {"rec": (jax.tree.map(lambda p, q: jnp.stack([p, q]),
                                              *rc) if collect else {}),
                         "attn": ac}
                return (x, aux + a), cache

            (x, aux), caches = jax.lax.scan(
                jax.checkpoint(period_body), (x, 0.0), params["periods"])
            tail_caches = None
            if "tail" in params:
                def tail_body(carry, lp):
                    x, c = _rec_block(lp, carry, cfg, policy, use_pallas,
                                      collect)
                    return x, c
                x, tail_caches = jax.lax.scan(jax.checkpoint(tail_body), x,
                                              params["tail"])
            if collect:
                out = {"rec": caches["rec"], "attn": caches["attn"]}
                if tail_caches is not None:
                    out["tail"] = tail_caches
                caches = out
            return x, aux, caches

        def layer_body(carry, lp):
            x, aux = carry
            x, a, cache = _attn_block(lp, x, cfg, positions, policy, 0,
                                      use_pallas, collect, max_seq,
                                      moe_ep=self.moe_ep)
            return (x, aux + a), cache

        (x, aux), caches = jax.lax.scan(
            jax.checkpoint(layer_body), (x, 0.0), params["layers"])
        return x, aux, caches

    # ---- training --------------------------------------------------------
    def loss(self, params, batch, policy: Optional[Policy] = None):
        """batch: tokens (B,S) [or embeds (B,S,d)] + labels
        (B,S) or (B,S,n_codebooks).  Returns (loss, metrics)."""
        cfg = self.cfg
        x = self._embed(params, batch, policy)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, aux, _ = self._backbone(params, x, positions, policy)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)

        labels = batch["labels"]
        n_chunks = max(S // LOSS_CHUNK, 1)
        hs = h.reshape(B, n_chunks, S // n_chunks, cfg.d_model)
        ls = labels.reshape((B, n_chunks, S // n_chunks) + labels.shape[2:])

        def chunk_loss(carry, xs):
            hc, lc = xs            # (B, C, d), (B, C[, cb])
            # cast AFTER the matmul: the convert's transpose casts the
            # cotangent back to bf16, keeping the whole backward pass (and
            # its collectives) in bf16 instead of fp32
            logits = (hc @ params["head"]).astype(jnp.float32)
            if cfg.n_codebooks:
                logits = logits.reshape(hc.shape[:2] +
                                        (cfg.n_codebooks, cfg.vocab))
            return carry + cross_entropy(logits, lc), None

        total, _ = jax.lax.scan(
            chunk_loss, 0.0,
            (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ls, 1, 0)))
        ce = total / n_chunks
        loss = ce + cfg.router_aux_weight * aux if cfg.is_moe else ce
        return loss, {"ce": ce, "aux": aux}

    # ---- serving ---------------------------------------------------------
    def prefill(self, params, batch, max_seq: int,
                policy: Optional[Policy] = None, last_pos=None):
        """Forward over the prompt; returns (last-token logits, caches).

        ``last_pos`` ((B,) int32, optional) selects the position whose
        logits are returned instead of ``S - 1`` — the serving engine
        right-pads prompts to a shape bucket and needs the logits of each
        request's REAL last token.  Causality keeps hidden states at
        positions ``<= last_pos`` independent of the padding suffix, and
        the decode-side validity mask (``cache_pos <= pos``) hides the
        padded KV entries until decode overwrites them in place."""
        cfg = self.cfg
        x = self._embed(params, batch, policy)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, _, caches = self._backbone(params, x, positions, policy,
                                      collect=True, max_seq=max_seq)
        hl = h[:, -1] if last_pos is None else h[jnp.arange(B), last_pos]
        h = rms_norm(hl, params["final_norm"], cfg.norm_eps)
        logits = h @ params["head"]
        if cfg.n_codebooks:
            logits = logits.reshape(B, cfg.n_codebooks, cfg.vocab)
        return logits, caches

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        cfg = self.cfg
        dt = dtype or jnp.dtype(cfg.dtype)
        if cfg.layer_pattern == "rwkv":
            return init_rwkv_cache(cfg, cfg.n_layers, batch, dt)
        if cfg.layer_pattern == "griffin":
            n_periods, tail = divmod(cfg.n_layers, 3)
            rec = init_rglru_cache(cfg, n_periods * 2, batch, dt)
            cache = {
                "rec": jax.tree.map(
                    lambda t: t.reshape((n_periods, 2) + t.shape[1:]), rec),
                "attn": init_kv_cache(cfg, batch, max_seq, n_periods, dt,
                                      window=cfg.local_window,
                                      quant=self.kv_quant),
            }
            if tail:
                cache["tail"] = init_rglru_cache(cfg, tail, batch, dt)
            return cache
        return init_kv_cache(cfg, batch, max_seq, cfg.n_layers, dt,
                             quant=self.kv_quant)

    def decode_step(self, params, cache, batch, pos,
                    policy: Optional[Policy] = None):
        """One token for the whole stack.
        batch: tokens (B,) [or embeds (B, d)]; pos: (B,) int32.
        Returns (logits (B, vocab[, cb]), new_cache)."""
        cfg = self.cfg
        if cfg.input_embeds:
            x = batch["embeds"][:, None].astype(jnp.dtype(cfg.dtype))
            x = x @ params["in_proj"]
        else:
            x = params["embed"][batch["tokens"][:, None]]
        if policy is not None:
            x = policy.acts(x, "embeds_dec")

        if cfg.layer_pattern == "rwkv":
            def body(x, xs):
                lp, c = xs
                xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
                o, (tm_x, state) = rwkv6_time_mix(
                    lp["rwkv"]["tm"], xn, cfg,
                    x_prev=c["tm_x"], state=c["state"], policy=policy)
                h = x + o
                hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
                o2, cm_x = rwkv6_channel_mix(lp["rwkv"]["cm"], hn, cfg,
                                             x_prev=c["cm_x"])
                return h + o2, {"tm_x": tm_x.astype(c["tm_x"].dtype),
                                "cm_x": cm_x.astype(c["cm_x"].dtype),
                                "state": state}
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

        elif cfg.layer_pattern == "griffin":
            def rec_step(lp, x, c):
                xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
                o, nc = rglru_decode(lp["rglru"], xn, cfg, c, policy=policy)
                x = x + o
                x = x + ffn_apply(lp["ffn"],
                                  rms_norm(x, lp["ln2"], cfg.norm_eps),
                                  cfg.activation, policy=policy)
                return x, nc

            def period(x, xs):
                lp, c = xs
                ncs = []
                for i in range(2):
                    rp = jax.tree.map(lambda t: t[i], lp["rec"])
                    rc = jax.tree.map(lambda t: t[i], c["rec"])
                    x, nc = rec_step(rp, x, rc)
                    ncs.append(nc)
                ap = lp["attn"]
                xn = rms_norm(x, ap["ln1"], cfg.norm_eps)
                o, ac = attn_decode(ap["attn"], xn, cfg, c["attn"], pos,
                                    policy=policy, window=cfg.local_window)
                x = x + o
                x = x + ffn_apply(ap["ffn"],
                                  rms_norm(x, ap["ln2"], cfg.norm_eps),
                                  cfg.activation, policy=policy)
                new_c = {"rec": jax.tree.map(
                    lambda p, q: jnp.stack([p, q]), *ncs), "attn": ac}
                return x, new_c

            x, new_p = jax.lax.scan(
                period, x, (params["periods"],
                            {"rec": cache["rec"], "attn": cache["attn"]}))
            new_cache = {"rec": new_p["rec"], "attn": new_p["attn"]}
            if "tail" in params:
                def tail_body(x, xs):
                    lp, c = xs
                    return rec_step(lp, x, c)
                x, new_tail = jax.lax.scan(tail_body, x,
                                           (params["tail"], cache["tail"]))
                new_cache["tail"] = new_tail

        else:
            def body(x, xs):
                lp, c = xs
                xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
                o, nc = attn_decode(lp["attn"], xn, cfg, c, pos,
                                    policy=policy)
                h = x + o
                hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
                if cfg.is_moe:
                    f, _ = moe_apply(lp["moe"], hn, cfg, policy=policy)
                else:
                    f = ffn_apply(lp["ffn"], hn, cfg.activation,
                                  policy=policy)
                return h + f, nc
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

        h = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
        logits = h @ params["head"]
        if cfg.n_codebooks:
            logits = logits.reshape(h.shape[0], cfg.n_codebooks, cfg.vocab)
        return logits, new_cache
