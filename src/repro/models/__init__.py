"""Model substrate: assigned architectures as one composable Transformer."""
from .layers import P, Policy, cross_entropy, rms_norm
from .transformer import Transformer, model_spec

__all__ = ["Transformer", "model_spec", "P", "Policy", "cross_entropy",
           "rms_norm"]
