"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch,
expert-parallel grouped compute, optional dense-residual branch (Arctic).

Dispatch is the static-shape "dropping" formulation (GShard/Switch style,
sort-based like MaxText): tokens are sorted by assigned expert, ranked
within the expert, and tokens beyond ``capacity`` are dropped (their combine
weight is zero, residual passes through).  Expert weights are stacked with a
leading ``experts`` logical axis → sharded over the "model" mesh axis
(expert parallelism); the dispatch/combine scatters become all-to-alls under
GSPMD.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import P, Policy, ffn_apply, ffn_spec

__all__ = ["moe_spec", "moe_apply", "moe_apply_ep"]


def moe_spec(cfg, prefix_shape=(), prefix_names=()) -> Dict[str, Any]:
    pa, pn = tuple(prefix_shape), tuple(prefix_names)
    spec: Dict[str, Any] = {
        "router": P(pa + (cfg.d_model, cfg.n_experts),
                    pn + ("embed", "experts")),
        "experts": ffn_spec(cfg.d_model, cfg.d_ff, cfg.activation,
                            pa + (cfg.n_experts,), pn + ("experts",)),
    }
    if cfg.moe_dense_residual:
        spec["dense"] = ffn_spec(cfg.d_model, cfg.d_ff, cfg.activation,
                                 pa, pn)
    return spec


def _capacity(n_tokens: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, (cap + 7) // 8 * 8)   # pad to 8 for tiling friendliness


def moe_apply(params, x, cfg, *, policy: Optional[Policy] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (out, router aux loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = _capacity(T, E, k, cfg.capacity_factor)
    xf = x.reshape(T, d)

    # --- routing ----------------------------------------------------------
    logits = (xf.astype(jnp.float32) @
              params["router"].astype(jnp.float32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize

    # load-balancing aux loss (Switch):  E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch (static shapes) ------------------------------
    flat_e = expert_idx.reshape(-1)                           # (T*k,)
    flat_g = gate_vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)                     # token of slot
    order = jnp.argsort(flat_e)                               # group by e
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    # rank within expert = index - start offset of that expert's run
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - offsets[se]
    keep = rank < C
    slot = se * C + jnp.where(keep, rank, 0)                  # (T*k,)

    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C)].add(
        xf[st], mode="drop")                                  # OOB drops
    buf = buf.reshape(E, C, d)
    if policy is not None:
        buf = policy.acts(buf, "moe_buf")

    # --- expert compute: grouped FFN over stacked weights ------------------
    ew = params["experts"]
    if cfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, ew["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, ew["w_up"])
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", buf, ew["w_up"])))
    if policy is not None:
        h = policy.acts(h, "moe_hidden")
    y = jnp.einsum("ecf,efd->ecd", h, ew["w_down"])           # (E, C, d)
    y = y.reshape(E * C, d)
    if policy is not None:
        y = policy.acts(y.reshape(E, C, d), "moe_buf").reshape(E * C, d)

    # --- combine ------------------------------------------------------------
    gathered = y[jnp.where(keep, slot, 0)]                    # (T*k, d)
    w = jnp.where(keep, sg, 0.0).astype(jnp.float32)
    out = jnp.zeros((T, d), jnp.float32).at[st].add(
        gathered.astype(jnp.float32) * w[:, None])
    out = out.astype(x.dtype)

    if cfg.moe_dense_residual:
        out = out + ffn_apply(params["dense"], xf, cfg.activation,
                              policy=policy)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map implementation (§Perf iteration 1 for MoE):
# GSPMD's scatter-based partitioning of the einsum formulation replicates
# the dispatch buffers (≈10 TB of all-gather per step for qwen3-moe at
# 256 chips).  Here the parallelism is explicit: tokens stay sharded over
# (pod, data) and are replicated over "model"; each model column owns
# E/16 experts, dispatches ONLY its local tokens→local experts (zero
# communication), and a single psum over "model" combines expert outputs —
# per layer that is one (B_loc, S, d) all-reduce instead of buffer-sized
# all-gathers.  Expert weights stay FSDP-sharded over "data"; the body
# all-gathers them per layer (the standard per-layer FSDP gather) and the
# transpose of that gather reduce-scatters the weight grads.
# ---------------------------------------------------------------------------

def moe_apply_ep(params, x, cfg, mesh, *, policy: Optional[Policy] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model = "model"
    n_model = mesh.shape[model]
    has_data = "data" in mesh.shape
    assert E % n_model == 0, (E, n_model)
    E_loc = E // n_model
    gated = cfg.activation in ("swiglu", "geglu")

    def body(xl, router_w, ew):
        j = jax.lax.axis_index(model)
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        C = _capacity(T, E, k, cfg.capacity_factor)
        xf = xl.reshape(T, d)

        # FSDP gather of this column's expert weights (d dim over "data")
        if has_data:
            ew = {
                "w_up": jax.lax.all_gather(ew["w_up"], "data", axis=1,
                                           tiled=True),
                "w_down": jax.lax.all_gather(ew["w_down"], "data", axis=2,
                                             tiled=True),
                **({"w_gate": jax.lax.all_gather(ew["w_gate"], "data",
                                                 axis=1, tiled=True)}
                   if gated else {}),
            }

        logits = xf.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
            1.0 / (T * k))
        aux = E * jnp.sum(me * ce)

        flat_e = expert_idx.reshape(-1)
        flat_g = gate_vals.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), k)
        order = jnp.argsort(flat_e)
        se, sg, st = flat_e[order], flat_g[order], flat_t[order]
        counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(T * k) - offsets[se]
        local = (se >= j * E_loc) & (se < (j + 1) * E_loc)
        keep = (rank < C) & local
        le = jnp.where(local, se - j * E_loc, 0)
        slot = le * C + jnp.where(keep, rank, 0)

        buf = jnp.zeros((E_loc * C, d), xl.dtype)
        buf = buf.at[jnp.where(keep, slot, E_loc * C)].add(
            xf[st], mode="drop").reshape(E_loc, C, d)

        if gated:
            act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("ecd,edf->ecf", buf, ew["w_gate"])) * \
                jnp.einsum("ecd,edf->ecf", buf, ew["w_up"])
        else:
            h = jnp.square(jax.nn.relu(
                jnp.einsum("ecd,edf->ecf", buf, ew["w_up"])))
        y = jnp.einsum("ecf,efd->ecd", h, ew["w_down"]).reshape(E_loc * C, d)

        gathered = y[jnp.where(keep, slot, 0)]
        wgt = jnp.where(keep, sg, 0.0).astype(jnp.float32)
        out = jnp.zeros((T, d), jnp.float32).at[st].add(
            gathered.astype(jnp.float32) * wgt[:, None])
        # combine expert columns: one activation-sized all-reduce per
        # layer — in bf16 (halves the wire bytes; partial sums of ≤top_k
        # expert outputs are bf16-safe)
        out = jax.lax.psum(out.astype(xl.dtype), model)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(Bl, Sl, d), aux

    bspec = batch_axes if batch_axes else None
    ew_specs = {
        "w_up": P(model, "data" if has_data else None, None),
        "w_down": P(model, None, "data" if has_data else None),
    }
    if gated:
        ew_specs["w_gate"] = P(model, "data" if has_data else None, None)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None), ew_specs),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )(x, params["router"], params["experts"])

    if cfg.moe_dense_residual:
        out = out + ffn_apply(params["dense"], x.reshape(-1, d),
                              cfg.activation, policy=policy
                              ).reshape(B, S, d)
    return out, aux
