"""Data pipeline: deterministic synthetic LM stream + device prefetch."""
from .pipeline import PrefetchIterator, SyntheticLM
__all__ = ["PrefetchIterator", "SyntheticLM"]
