"""Synthetic LM data pipeline with planner-style prefetch.

The iterator is deterministic (seeded, stateless per index → a checkpoint
only needs the step counter) and double-buffered: batch i+1 is produced and
``advancedload``-ed (async ``jax.device_put``) while step i runs — the
training-loop instantiation of the paper's hoisted upload (Fig. 4b).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

__all__ = ["SyntheticLM", "PrefetchIterator"]


class SyntheticLM:
    """Deterministic synthetic token stream.

    Batch ``i`` is a pure function of (seed, i) — restart-safe and
    mesh-agnostic (the global batch is generated identically on every host;
    each host feeds its addressable shards)."""

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed

    def batch_at(self, index: int) -> Dict[str, np.ndarray]:
        """Learnable stream: tokens follow an affine recurrence
        t_{i+1} = (a·t_i + c) mod V with occasional random resets, labels
        are next-token — so cross-entropy decreasing below ln(V) is a real
        end-to-end signal (used by the e2e tests)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index]))
        cfg = self.cfg
        V = cfg.vocab
        out: Dict[str, np.ndarray] = {}
        toks = np.empty((self.batch, self.seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, (self.batch,))
        resets = rng.random((self.batch, self.seq)) < 0.05
        fresh = rng.integers(0, V, (self.batch, self.seq))
        for t in range(self.seq):
            nxt = (5 * toks[:, t] + 13) % V
            toks[:, t + 1] = np.where(resets[:, t], fresh[:, t], nxt)
        if cfg.input_embeds:
            out["embeds"] = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model)).astype(np.float32)
        else:
            out["tokens"] = toks[:, :-1].astype(np.int32)
        if cfg.n_codebooks:
            out["labels"] = rng.integers(
                0, V, (self.batch, self.seq, cfg.n_codebooks),
                dtype=np.int32)
        else:
            out["labels"] = toks[:, 1:].astype(np.int32)
        return out


class PrefetchIterator:
    """Double-buffered device prefetch (advancedload).

    A producer thread builds host batches and issues ``jax.device_put``
    (async under JAX) ``depth`` steps ahead; ``__next__`` returns an
    already-resident device batch.  ``state_dict``/``load_state_dict``
    round-trips the cursor for checkpoint/restart."""

    def __init__(self, source: SyntheticLM, start_index: int = 0,
                 depth: int = 2, shardings: Optional[Any] = None):
        self.source = source
        self.index = start_index
        self.depth = depth
        self.shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _put_device(self, host_batch):
        if self.shardings is not None:
            return {k: jax.device_put(v, self.shardings[k])
                    for k, v in host_batch.items()}
        return {k: jax.device_put(v) for k, v in host_batch.items()}

    def _producer(self):
        idx = self.index
        while not self._stop.is_set():
            batch = self._put_device(self.source.batch_at(idx))
            while not self._stop.is_set():
                try:
                    self._q.put((idx, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            idx += 1

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        idx, batch = self._q.get()
        self.index = idx + 1
        return batch

    def state_dict(self) -> Dict[str, int]:
        return {"index": self.index}

    @classmethod
    def restore(cls, source: SyntheticLM, state: Dict[str, int],
                **kw) -> "PrefetchIterator":
        return cls(source, start_index=state["index"], **kw)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
