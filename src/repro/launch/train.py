"""End-to-end training driver.

The host↔device schedule is the paper's optimized plan, realized on the
training loop:

  * advancedload — the data pipeline device_puts batch i+1 while step i runs
    (``PrefetchIterator``), and optimizer state streams in from pinned_host
    when ``--offload-opt`` (XLA-overlapped);
  * delegatestore — metrics stay on device and are fetched only at log
    steps (JAX async dispatch keeps the loop ahead); checkpoints copy
    device→host immediately and hit disk on a background thread;
  * noupdate — params/optimizer state never move (donated buffers);
  * synchronize — a single block_until_ready at log/checkpoint boundaries.

Fault tolerance: auto-resume from the latest checkpoint, optional injected
failures (--fail-at) exercising the restart path, straggler watchdog
logging.  Works on CPU with reduced configs (the smoke-scale path the tests
run) and is mesh-ready for real pods.

    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-3b --reduced \
        --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced as reduce_cfg
from repro.data import PrefetchIterator, SyntheticLM
from repro.models import Transformer
from repro.optim import default_optimizer
from repro.runtime import FaultInjector, StepWatchdog

def make_train_step(model, opt):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics}
    return jax.jit(train_step, donate_argnums=(0, 1))


def train(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str,
          ckpt_every: int = 10, log_every: int = 5, seed: int = 0,
          injector: Optional[FaultInjector] = None,
          resume: bool = True) -> dict:
    model = Transformer(cfg)
    opt = default_optimizer(cfg)
    ckpt = CheckpointManager(ckpt_dir)
    watchdog = StepWatchdog()

    params = model.init(jax.random.key(seed))
    opt_state = opt.init(params)
    start_step = 0
    state_tree = {"params": params, "opt": opt_state}
    if resume:
        restored = ckpt.restore_latest(state_tree)
        if restored is not None:
            start_step, state_tree, extra = restored
            print(f"[train] resumed from step {start_step}")
    params, opt_state = state_tree["params"], state_tree["opt"]

    source = SyntheticLM(cfg, batch, seq, seed=seed)
    it = PrefetchIterator(source, start_index=start_step)   # advancedload
    step_fn = make_train_step(model, opt)

    losses = []
    t_start = time.perf_counter()
    try:
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            batch_dev = next(it)
            if injector is not None:
                injector.maybe_fail(step)
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_dev)
            # metrics stay on device (delegatestore deferred until the
            # log step below forces the sync)
            if (step + 1) % log_every == 0 or step + 1 == steps:
                loss = float(metrics["loss"])      # ← the sync point
                losses.append((step + 1, loss))
                dt = time.perf_counter() - t0
                watchdog.record("host0", dt)
                print(f"[train] step {step + 1:5d} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms)"
                      + (" STRAGGLER" if watchdog.stragglers() else ""))
            if (step + 1) % ckpt_every == 0 or step + 1 == steps:
                ckpt.save(step + 1,
                          {"params": params, "opt": opt_state},
                          extra={"data_index": step + 1})
    finally:
        it.close()
        ckpt.wait()                                # final synchronize
    wall = time.perf_counter() - t_start
    return {"losses": losses, "final_step": steps, "wall_s": wall,
            "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (restart demo)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    injector = FaultInjector(tuple(args.fail_at)) if args.fail_at else None

    attempts = 0
    while True:
        try:
            out = train(cfg, steps=args.steps, batch=args.batch,
                        seq=args.seq, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, injector=injector)
            break
        except RuntimeError as e:
            attempts += 1
            print(f"[train] FAILURE ({e}); restarting from latest "
                  f"checkpoint (attempt {attempts})")
            if attempts > 5:
                raise
    first = out["losses"][0][1] if out["losses"] else float("nan")
    last = out["losses"][-1][1] if out["losses"] else float("nan")
    print(f"[train] done: steps={out['final_step']} "
          f"loss {first:.4f} -> {last:.4f} wall={out['wall_s']:.1f}s "
          f"restarts={attempts}")


if __name__ == "__main__":
    main()
