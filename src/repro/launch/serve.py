"""Serving driver: batched prefill + greedy decode with donated caches.

Residency policy (the paper's, applied to serving): weights and KV caches
are uploaded once and stay device-resident (noupdate); per-request tokens
are the only per-step host→device transfer (advancedload of a few bytes);
sampled tokens are fetched back lazily in batches (delegatestore).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import Transformer


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    model = Transformer(cfg)
    params = model.init(jax.random.key(seed))     # resident (noupdate)
    rng = np.random.default_rng(seed)
    max_seq = prompt_len + gen

    if cfg.input_embeds:
        prompt = {"embeds": jnp.asarray(rng.standard_normal(
            (batch, prompt_len, cfg.d_model), dtype=np.float32))}
    else:
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt)
    if cfg.n_codebooks:
        logits = logits[..., 0, :]
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        if cfg.input_embeds:
            step_in = {"embeds": jnp.zeros((batch, cfg.d_model),
                                           jnp.float32)}
        else:
            step_in = {"tokens": tok}
        logits, cache = decode(params, cache, step_in, pos)
        if cfg.n_codebooks:
            logits = logits[..., 0, :]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    # delegatestore: one fetch for the whole generation
    generated = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    t_decode = time.perf_counter() - t0
    return {
        "generated": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * gen / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen)
    print(f"[serve] generated shape {out['generated'].shape} "
          f"prefill={out['prefill_s']:.2f}s decode={out['decode_s']:.2f}s "
          f"({out['tokens_per_s']:.0f} tok/s)")
    print("[serve] sample:", out["generated"][0][:12])


if __name__ == "__main__":
    main()
