"""Serving driver: batched prefill + greedy decode with donated caches.

Residency policy (the paper's, applied to serving): weights and KV caches
are uploaded once and stay device-resident (noupdate); per-request tokens
are the only per-step host→device transfer (advancedload of a few bytes);
sampled tokens are fetched back lazily in batches (delegatestore).

``serve()`` is the one-shot static-batch path: one group of ``batch``
identical requests, prefill + ``gen - 1`` decode steps.  The continuous-
batching engine (``repro.serve``) generalizes it to request-level
scheduling; ``--engine`` runs a seeded open-loop trace through it.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 16 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --engine --n-requests 24 --rate 50 --capacity 4 --policy fcfs
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import Transformer


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    model = Transformer(cfg)
    params = model.init(jax.random.key(seed))     # resident (noupdate)
    rng = np.random.default_rng(seed)
    max_seq = prompt_len + gen

    if cfg.input_embeds:
        prompt = {"embeds": jnp.asarray(rng.standard_normal(
            (batch, prompt_len, cfg.d_model), dtype=np.float32))}
    else:
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt)
    if cfg.n_codebooks:
        logits = logits[..., 0, :]
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        if cfg.input_embeds:
            step_in = {"embeds": jnp.zeros((batch, cfg.d_model),
                                           jnp.float32)}
        else:
            step_in = {"tokens": tok}
        logits, cache = decode(params, cache, step_in, pos)
        if cfg.n_codebooks:
            logits = logits[..., 0, :]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    # delegatestore: one fetch for the whole generation
    generated = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    t_decode = time.perf_counter() - t0
    # gen == 1 never enters the decode loop: the only token comes from the
    # prefill, so decode throughput is 0 by definition (not prefill tokens
    # divided by an ~empty decode timer, which reported nonsense here).
    decode_tok_s = (batch * (gen - 1) / max(t_decode, 1e-9)
                    if gen > 1 else 0.0)
    total = t_prefill + t_decode
    return {
        "generated": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": decode_tok_s,
        "tokens_per_s": batch * gen / max(total, 1e-9),
    }


def run_engine(cfg, *, n_requests: int, rate_rps: float, capacity: int,
               policy: str, join_policy: str = "continuous",
               max_seq: int = 64, seed: int = 0,
               respect_arrivals: bool = True):
    """Replay a seeded open-loop trace through the continuous-batching
    engine (``repro.serve``) and return its report."""
    from repro.serve import Engine, ServeRuntime, make_trace
    rt = ServeRuntime(cfg, max_seq=max_seq, seed=seed)
    eng = Engine(rt, capacity=capacity, join_policy=join_policy,
                 policy=policy)
    reqs = make_trace(cfg, n_requests=n_requests, rate_rps=rate_rps,
                      seed=seed, max_seq=max_seq)
    return eng.run(reqs, respect_arrivals=respect_arrivals)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine over a seeded trace")
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="open-loop arrival rate (requests/s)")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--policy", default="fcfs", choices=("fcfs", "sjf"))
    ap.add_argument("--join-policy", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)

    if args.engine:
        rep = run_engine(cfg, n_requests=args.n_requests,
                         rate_rps=args.rate, capacity=args.capacity,
                         policy=args.policy, join_policy=args.join_policy,
                         max_seq=args.max_seq, seed=args.seed)
        print(f"[serve.engine] {rep['n_requests']} requests in "
              f"{rep['wall_s']:.2f}s  {rep['requests_per_s']:.1f} req/s  "
              f"{rep['tokens_per_s']:.0f} tok/s  "
              f"p50={rep['latency_p50_s']*1e3:.0f}ms "
              f"p99={rep['latency_p99_s']*1e3:.0f}ms  "
              f"occupancy={rep['occupancy']:.2f}")
        print(f"[serve.engine] tune: {rep['tune']['measurements']} measured "
              f"/ {rep['tune']['hits']} cached  pool: {rep['pool']}")
        return

    out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen)
    print(f"[serve] generated shape {out['generated'].shape} "
          f"prefill={out['prefill_s']:.2f}s decode={out['decode_s']:.2f}s "
          f"({out['tokens_per_s']:.0f} tok/s end-to-end, "
          f"{out['decode_tok_s']:.0f} tok/s decode)")
    print("[serve] sample:", out["generated"][0][:12])


if __name__ == "__main__":
    main()
