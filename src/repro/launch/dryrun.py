import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh(es) and record memory/cost/collective artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --outdir artifacts/dryrun

The two XLA_FLAGS lines above MUST run before any other import (jax locks
the device count on first init); smoke tests and benches never import this
module, so they see the real single CPU device.
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

# imported for effect: locks the 512-device host platform configured above
import jax               # noqa: E402,F401

from repro.configs import SHAPES, get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.steps import build_cell                 # noqa: E402
from repro.roofline import roofline_terms                 # noqa: E402

DEFAULT_TRAIN_ACCUM = 4   # fits every train cell within 16 GB/chip


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
             *, save_hlo: bool = False, variant: str = "baseline",
             overrides=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]

    # spec'd skip: long_500k needs sub-quadratic attention
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "SKIP",
               "reason": "pure full-attention arch; long_500k requires "
                         "sub-quadratic attention (DESIGN.md §6)"}
        _write(outdir, rec, variant)
        print(f"SKIP  {arch} × {shape_name}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    kwargs = dict(overrides or {})
    if shape.kind == "train":
        kwargs.setdefault("grad_accum", DEFAULT_TRAIN_ACCUM)
    grad_accum = kwargs.get("grad_accum", 1)
    cell = build_cell(cfg, shape, mesh, **kwargs)
    with mesh:
        lowered = cell.lower()
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    n_dev = mesh.size
    roof = roofline_terms(cfg, shape, n_dev, hlo, grad_accum=grad_accum,
                          kv_bytes=1 if kwargs.get("kv_quant") else 2)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(mesh.shape),
        "status": "OK",
        "variant": variant,
        "kind": shape.kind,
        "optimizer": cell.meta.get("optimizer"),
        "grad_accum": grad_accum,
        "dropped_shardings": cell.meta.get("dropped", []),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "xla_flops_per_device_raw": ca.get("flops", 0.0),
        "xla_bytes_per_device_raw": ca.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(ma, "generated_code_size_in_bytes", None),
        },
        "roofline": roof,
        "n_devices": n_dev,
    }
    _write(outdir, rec, variant)
    if save_hlo:
        (outdir / f"{arch}__{shape_name}__"
         f"{'multi' if multi_pod else 'single'}__{variant}.hlo.txt"
         ).write_text(hlo)
    tot_coll_mb = sum(
        v["bytes"] for v in roof["collectives"].values()) / 1e6
    print(f"OK    {arch} × {shape_name} × "
          f"{'multi' if multi_pod else 'single'} [{variant}] "
          f"compile={t_compile:.0f}s "
          f"temp/dev={rec['memory']['temp_bytes']/1e9:.2f}GB "
          f"terms(c/m/n)={roof['compute_s']:.3f}/"
          f"{roof['memory_s']:.3f}/{roof['collective_s']:.3f}s "
          f"bottleneck={roof['bottleneck']} "
          f"roofline={roof['roofline_fraction']:.2f} "
          f"coll={tot_coll_mb:.0f}MB")
    return rec


def _write(outdir: Path, rec, variant: str):
    outdir.mkdir(parents=True, exist_ok=True)
    name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
            f"__{variant}.json")
    (outdir / name).write_text(json.dumps(rec, indent=2, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="artifacts/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--offload-opt", action="store_true")
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel shard_map MoE")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--fsdp-layers", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    archs = list(list_archs()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = Path(args.outdir)
    overrides = {}
    if args.offload_opt:
        overrides["offload_opt"] = True
    if args.moe_ep:
        overrides["moe_ep"] = True
    if args.grad_accum is not None:
        overrides["grad_accum"] = args.grad_accum
    if args.kv_quant:
        overrides["kv_quant"] = True
    if args.fsdp_layers:
        overrides["fsdp_layers"] = True
    if args.seq_shard:
        overrides["seq_shard"] = True

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    run_cell(arch, shape, multi, outdir,
                             save_hlo=args.save_hlo, variant=args.variant,
                             overrides=overrides)
                except Exception as e:
                    failures.append((arch, shape, multi, repr(e)))
                    print(f"FAIL  {arch} × {shape} × "
                          f"{'multi' if multi else 'single'}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
