"""Step builders: abstract input specs + sharded jitted step functions for
every (arch × shape) cell.  Used by the dry-run, the train/serve drivers and
the benchmarks."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ArchConfig, ShapeSpec
from repro.distributed import (MeshPolicy, batch_specs, cache_shardings,
                               make_rules, tree_shardings)
from repro.models import Transformer
from repro.optim import (default_optimizer, offload_shardings,
                         offloaded_optimizer)

__all__ = ["input_specs", "build_cell", "CellArtifacts"]


def input_specs(cfg: ArchConfig, shape: ShapeSpec
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        out: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.input_embeds:
            out["embeds"] = jax.ShapeDtypeStruct((B, cfg.d_model), dt)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        return out
    out = {}
    if cfg.input_embeds:
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        lshape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
        out["labels"] = jax.ShapeDtypeStruct(lshape, jnp.int32)
    return out


class CellArtifacts:
    """Everything needed to lower one (arch × shape × mesh) cell."""

    def __init__(self, fn, args_abstract: Tuple[Any, ...],
                 donate: Tuple[int, ...], in_shardings, out_shardings,
                 meta: Dict[str, Any]):
        self.fn = fn
        self.args_abstract = args_abstract
        self.donate = donate
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.meta = meta

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        return self.jitted().lower(*self.args_abstract)


def _opt_state_shardings(mesh, aparams, p_sh, aopt, opt_name: str):
    """Optimizer-state shardings mirroring the param shardings."""
    rep = NamedSharding(mesh, PartitionSpec())
    if opt_name == "adamw":
        return {"m": p_sh, "v": p_sh, "step": rep}

    def factor_sh(p, s):
        spec = tuple(s.spec) + (None,) * (p.ndim - len(tuple(s.spec)))
        if p.ndim >= 2:
            return {
                "vr": NamedSharding(mesh, PartitionSpec(*spec[:-1])),
                "vc": NamedSharding(mesh,
                                    PartitionSpec(*(spec[:-2] + spec[-1:]))),
            }
        return {"v": s}

    return {
        "factors": jax.tree.map(factor_sh, aparams, p_sh,
                                is_leaf=lambda x: hasattr(x, "shape")),
        "step": rep,
    }


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
               use_pallas: bool = False, offload_opt: bool = False,
               remat: bool = True, grad_accum: int = 1,
               moe_ep: bool = False,
               kv_quant: bool = False,
               fsdp_layers: bool = False,
               seq_shard: bool = False) -> CellArtifacts:
    model = Transformer(cfg, use_pallas=use_pallas, moe_ep=moe_ep,
                        kv_quant=kv_quant)
    kind = shape.kind
    rules = make_rules(mesh, kind, fsdp_layers=fsdp_layers)
    policy = MeshPolicy(rules, cfg, seq_shard=seq_shard)
    aparams = model.abstract_params()
    p_sh = tree_shardings(rules, aparams, model.logical_axes())
    ispecs = input_specs(cfg, shape)
    b_sh = batch_specs(rules, cfg, kind, ispecs)
    rep = NamedSharding(mesh, PartitionSpec())
    meta = {"arch": cfg.name, "shape": shape.name, "kind": kind,
            "mesh_shape": dict(mesh.shape), "dropped": rules.dropped,
            "kv_quant": kv_quant, "fsdp_layers": fsdp_layers,
            "moe_ep": moe_ep}

    if kind == "train":
        opt = default_optimizer(cfg)
        aopt = jax.eval_shape(opt.init, aparams)
        o_sh = _opt_state_shardings(mesh, aparams, p_sh, aopt, opt.name)
        if offload_opt:
            o_sh = offload_shardings(o_sh)
            opt = offloaded_optimizer(opt)
        meta["optimizer"] = opt.name

        def train_step(params, opt_state, batch):
            if grad_accum > 1:
                mbs = jax.tree.map(
                    lambda t: t.reshape(
                        (grad_accum, t.shape[0] // grad_accum)
                        + t.shape[1:]), batch)

                def mb_body(acc, mb):
                    g_acc, l_acc = acc
                    (loss, _), grads = jax.value_and_grad(
                        model.loss, has_aux=True)(params, mb, policy)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32),
                        g_acc, grads)
                    return (g_acc, l_acc + loss), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(mb_body, (g0, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / grad_accum, grads)
                loss = loss / grad_accum
                metrics = {"ce": loss, "aux": 0.0}
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch, policy)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, {"loss": loss, **metrics}

        batch_abs = {k: ispecs[k] for k in ispecs}
        return CellArtifacts(
            fn=train_step,
            args_abstract=(aparams, aopt, batch_abs),
            donate=(0, 1),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh,
                           jax.tree.map(lambda _: rep,
                                        {"loss": 0, "ce": 0, "aux": 0})),
            meta=meta,
        )

    if kind == "prefill":
        max_seq = shape.seq_len

        def prefill(params, batch):
            return model.prefill(params, batch, max_seq=max_seq,
                                 policy=policy)

        acache = jax.eval_shape(prefill, aparams, dict(ispecs))[1]
        c_sh = cache_shardings(rules, acache)
        return CellArtifacts(
            fn=prefill,
            args_abstract=(aparams, dict(ispecs)),
            donate=(),
            in_shardings=(p_sh, b_sh),
            out_shardings=(rep, c_sh),
            meta=meta,
        )

    # decode
    max_seq = shape.seq_len
    B = shape.global_batch
    acache = jax.eval_shape(
        lambda: model.init_cache(B, max_seq))
    c_sh = cache_shardings(rules, acache)
    pos_spec = ispecs.pop("pos")

    def serve_step(params, cache, batch, pos):
        logits, new_cache = model.decode_step(params, cache, batch, pos,
                                              policy=policy)
        # greedy next token — the serving driver feeds it back
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    tok_sh = batch_specs(rules, cfg, kind, ispecs)
    p_axes = batch_specs(rules, cfg, kind, {"pos": pos_spec})["pos"]
    ntok_ndim = 2 if cfg.n_codebooks else 1
    return CellArtifacts(
        fn=serve_step,
        args_abstract=(aparams, acache, dict(ispecs), pos_spec),
        donate=(1,),
        in_shardings=(p_sh, c_sh, tok_sh, p_axes),
        out_shardings=(NamedSharding(
            mesh, PartitionSpec(*([None] * ntok_ndim))), c_sh),
        meta=meta,
    )
