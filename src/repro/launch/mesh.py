"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches JAX
device state.  Production target: TPU v5e pods of 256 chips, 16×16
("data", "model"); the multi-pod variant stacks a leading "pod" axis
(2×16×16 = 512 chips) used for cross-pod data parallelism (or pipeline
stages — see distributed/pipeline.py).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Small meshes for tests (e.g. (2, 4) on 8 forced host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
