"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches JAX
device state.  Production target: TPU v5e pods of 256 chips, 16×16
("data", "model"); the multi-pod variant stacks a leading "pod" axis
(2×16×16 = 512 chips) used for cross-pod data parallelism (or pipeline
stages — see distributed/pipeline.py).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def _make(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older make_mesh has no
    # axis_types kwarg (everything is implicitly Auto there).
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Small meshes for tests (e.g. (2, 4) on 8 forced host devices)."""
    return _make(shape, axes)
