"""Optimizers: AdamW, Adafactor, host-offloaded state (paper technique)."""
from .adafactor import adafactor
from .adamw import Optimizer, adamw
from .offload import (attention_step_program, host_memory_kind,
                      offload_shardings, offloaded_optimizer,
                      plan_step_program, supports_pinned_host)


def default_optimizer(cfg) -> Optimizer:
    """Adafactor for the 480B MoE (Adam fp32 state > one pod's HBM);
    AdamW elsewhere."""
    from repro.configs import param_count
    if param_count(cfg) > 100e9:
        return adafactor()
    return adamw()


__all__ = ["adamw", "adafactor", "Optimizer", "default_optimizer",
           "offload_shardings", "offloaded_optimizer",
           "plan_step_program", "attention_step_program",
           "host_memory_kind", "supports_pinned_host"]
