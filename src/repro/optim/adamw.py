"""AdamW with fp32 state over bf16 params (functional, pytree-native)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "Optimizer"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]
    name: str = "optimizer"


def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        else:
            scale = 1.0

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / (1 - b1 ** step.astype(jnp.float32))
            vh = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_p = jax.tree.leaves(params)
        new = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([n[0] for n in new])
        new_state = {
            "m": tdef.unflatten([n[1] for n in new]),
            "v": tdef.unflatten([n[2] for n in new]),
            "step": step,
        }
        return new_p, new_state

    return Optimizer(init=init, update=update, name="adamw")
