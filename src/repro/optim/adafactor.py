"""Adafactor (Shazeer & Stern, 2018): factored second moments — the default
for arctic-480b, whose full Adam fp32 state would not fit one pod's HBM.
Params with ndim ≥ 2 store row/col factor vectors instead of a full second
moment (the two trailing dims are factored; leading stack dims ride along),
so state is ~1 % of Adam's."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import Optimizer

__all__ = ["adafactor"]


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    def init(params):
        def state_for(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "factors": jax.tree.map(state_for, params,
                                    is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None],
                                eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                denom = jnp.sqrt(v)
                new_s = {"v": v}
            u = g / jnp.maximum(denom, eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        def is_leaf(x):
            return isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = jax.tree.flatten(state["factors"], is_leaf=is_leaf)[0]
        flat_p = jax.tree.leaves(params)
        new = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (tdef.unflatten([n[0] for n in new]),
                {"factors": tdef.unflatten([n[1] for n in new]),
                 "step": step})

    return Optimizer(init=init, update=update, name="adafactor")
