"""Optimizer-state host offload — the paper's technique at training scale.

HMPP's ``advancedload``/``delegatestore`` become memory-kind transfers:
optimizer state lives in ``pinned_host`` memory and is streamed to the
device right before the update (advancedload, overlapped by XLA with the
backward pass) and streamed back after (delegatestore, overlapped with the
next step's forward).  Concretely this is just a sharding transform — the
jitted step's in/out shardings for the optimizer state carry
``memory_kind="pinned_host"`` and XLA inserts the transfers.

JAX-version compatibility: the memory-space API has moved around
(``jax.memory.Space`` is newer than some installed jaxlibs, and CPU builds
expose no ``pinned_host`` space at all), so this module probes what the
runtime actually supports — ``host_memory_kind()`` returns the usable host
kind or ``None`` — and every transform degrades to an identity when host
memory is unavailable, keeping one code path for CPU CI and TPU prod.

``offload_shardings`` converts a device sharding tree; ``plan_step_program``
builds the equivalent explicit block-``Program`` (host update blocks +
device compute blocks) so the offload schedule can be *inspected* with the
paper's emitter and counted by the executor — used in tests and the
train-overlap benchmark.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax

from repro.core import Program

__all__ = ["offload_shardings", "offloaded_optimizer", "plan_step_program",
           "host_memory_kind", "supports_pinned_host"]

_HOST_KIND = "pinned_host"


@functools.lru_cache(maxsize=None)
def _device_memory_kinds(device) -> tuple:
    try:
        return tuple(m.kind for m in device.addressable_memories())
    except Exception:
        return ()


def host_memory_kind(device=None) -> Optional[str]:
    """The host-side memory kind usable for offload on ``device``, or
    ``None`` when the platform has no addressable host space distinct from
    its default memory (e.g. CPU jaxlib: everything is unpinned_host)."""
    if device is None:
        device = jax.devices()[0]
    return _HOST_KIND if _HOST_KIND in _device_memory_kinds(device) else None


def supports_pinned_host(device=None) -> bool:
    return host_memory_kind(device) is not None


def _transfer_to(kind: str):
    """A placement target usable inside jit, across JAX versions."""
    space = getattr(jax, "memory", None)
    if space is not None and hasattr(space, "Space"):
        return space.Space.Host if kind == _HOST_KIND else space.Space.Device
    ttmk = getattr(jax.sharding, "TransferToMemoryKind", None)
    if ttmk is None:
        from jax._src.sharding_impls import TransferToMemoryKind as ttmk
    return ttmk(kind)


def offload_shardings(sharding_tree):
    """Move a sharding tree's memory kind to the host space; identity when
    the platform has none (the optimizer then simply stays on device)."""
    kind = host_memory_kind()
    if kind is None:
        return sharding_tree
    return jax.tree.map(
        lambda s: s.with_memory_kind(kind), sharding_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))


def _to_space(tree, kind: str):
    if host_memory_kind() is None:
        return tree     # single memory space: nothing to move
    target = _transfer_to(kind)
    return jax.tree.map(
        lambda x: jax.device_put(x, target)
        if hasattr(x, "ndim") and x.ndim > 0 else x, tree)


def offloaded_optimizer(opt):
    """Wrap an Optimizer so its state lives in host memory: the update
    streams state in (advancedload — XLA overlaps it with the backward
    pass that produces the grads) and the new state back out
    (delegatestore, overlapped with the next forward)."""
    def update(grads, state, params):
        state_dev = _to_space(state, "device")
        new_p, new_s = opt.update(grads, state_dev, params)
        return new_p, _to_space(new_s, _HOST_KIND)

    return dataclasses.replace(opt, update=update,
                               name=opt.name + "+offload")


def plan_step_program(n_steps: int = 4) -> Program:
    """A miniature training loop as a block program: host data producer,
    device fwd/bwd codelet, device optimizer update reading offloaded state,
    host metric logging — the planner hoists the batch upload (prefetch) and
    sinks the metric download (lazy fetch), exactly the schedule train.py
    implements with real arrays."""
    import numpy as np

    p = Program("train_loop")
    p.bind("w", np.zeros((64, 64), np.float32))
    p.bind("opt_m", np.zeros((64, 64), np.float32))
    p.bind("seed", np.zeros((2,), np.float32))

    p.host(lambda xp, seed: {"batch": xp.outer(seed + 1.0,
                                               xp.ones(64, xp.float32))},
           reads=("seed",), writes=("batch",), name="next_batch")
    with p.loop(n_steps):
        p.offload(lambda xp, w, batch:
                  {"grad": (w @ batch.T @ batch) / 64.0,
                   "loss": ((batch @ w) ** 2).sum(keepdims=True)[:1]},
                  reads=("w", "batch"), writes=("grad", "loss"),
                  name="fwd_bwd")
        p.offload(lambda xp, w, grad, opt_m:
                  {"w": w - 0.1 * (0.9 * opt_m + grad),
                   "opt_m": 0.9 * opt_m + grad},
                  reads=("w", "grad", "opt_m"), writes=("w", "opt_m"),
                  name="opt_update")
    p.host(lambda xp, loss: {"final_loss": loss},
           reads=("loss",), writes=("final_loss",), name="log_metrics")
    p.set_outputs("final_loss", "w")
    return p
