"""Optimizer-state host offload — the paper's technique at training scale.

HMPP's ``advancedload``/``delegatestore`` become memory-kind transfers:
optimizer state lives in ``pinned_host`` memory and is streamed to the
device right before the update (advancedload, overlapped by XLA with the
backward pass) and streamed back after (delegatestore, overlapped with the
next step's forward).  Concretely this is just a sharding transform — the
jitted step's in/out shardings for the optimizer state carry
``memory_kind="pinned_host"`` and XLA inserts the transfers.

``offload_shardings`` converts a device sharding tree; ``plan_step_program``
builds the equivalent explicit block-``Program`` (host update blocks +
device compute blocks) so the offload schedule can be *inspected* with the
paper's emitter and counted by the executor — used in tests and the
train-overlap benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core import Program

__all__ = ["offload_shardings", "offloaded_optimizer", "plan_step_program"]


def offload_shardings(sharding_tree):
    return jax.tree.map(
        lambda s: s.with_memory_kind("pinned_host"), sharding_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))


def _to_space(tree, space):
    return jax.tree.map(
        lambda x: jax.device_put(x, space)
        if hasattr(x, "ndim") and x.ndim > 0 else x, tree)


def offloaded_optimizer(opt):
    """Wrap an Optimizer so its state lives in host memory: the update
    streams state in (advancedload — XLA overlaps it with the backward
    pass that produces the grads) and the new state back out
    (delegatestore, overlapped with the next forward)."""
    def update(grads, state, params):
        state_dev = _to_space(state, jax.memory.Space.Device)
        new_p, new_s = opt.update(grads, state_dev, params)
        return new_p, _to_space(new_s, jax.memory.Space.Host)

    return dataclasses.replace(opt, update=update,
                               name=opt.name + "+offload")


def plan_step_program(n_steps: int = 4) -> Program:
    """A miniature training loop as a block program: host data producer,
    device fwd/bwd codelet, device optimizer update reading offloaded state,
    host metric logging — the planner hoists the batch upload (prefetch) and
    sinks the metric download (lazy fetch), exactly the schedule train.py
    implements with real arrays."""
    import numpy as np

    p = Program("train_loop")
    p.bind("w", np.zeros((64, 64), np.float32))
    p.bind("opt_m", np.zeros((64, 64), np.float32))
    p.bind("seed", np.zeros((2,), np.float32))

    p.host(lambda xp, seed: {"batch": xp.outer(seed + 1.0,
                                               xp.ones(64, xp.float32))},
           reads=("seed",), writes=("batch",), name="next_batch")
    with p.loop(n_steps):
        p.offload(lambda xp, w, batch:
                  {"grad": (w @ batch.T @ batch) / 64.0,
                   "loss": ((batch @ w) ** 2).sum(keepdims=True)[:1]},
                  reads=("w", "batch"), writes=("grad", "loss"),
                  name="fwd_bwd")
        p.offload(lambda xp, w, grad, opt_m:
                  {"w": w - 0.1 * (0.9 * opt_m + grad),
                   "opt_m": 0.9 * opt_m + grad},
                  reads=("w", "grad", "opt_m"), writes=("w", "opt_m"),
                  name="opt_update")
    p.host(lambda xp, loss: {"final_loss": loss},
           reads=("loss",), writes=("final_loss",), name="log_metrics")
    p.set_outputs("final_loss", "w")
    return p
