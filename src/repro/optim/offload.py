"""Optimizer-state host offload — the paper's technique at training scale.

HMPP's ``advancedload``/``delegatestore`` become memory-kind transfers:
optimizer state lives in ``pinned_host`` memory and is streamed to the
device right before the update (advancedload, overlapped by XLA with the
backward pass) and streamed back after (delegatestore, overlapped with the
next step's forward).  Concretely this is just a sharding transform — the
jitted step's in/out shardings for the optimizer state carry
``memory_kind="pinned_host"`` and XLA inserts the transfers.

JAX-version compatibility: the memory-space API has moved around
(``jax.memory.Space`` is newer than some installed jaxlibs, and CPU builds
expose no ``pinned_host`` space at all), so this module probes what the
runtime actually supports — ``host_memory_kind()`` returns the usable host
kind or ``None`` — and every transform degrades to an identity when host
memory is unavailable, keeping one code path for CPU CI and TPU prod.

``offload_shardings`` converts a device sharding tree; ``plan_step_program``
builds the equivalent explicit block-``Program`` (host update blocks +
device compute blocks) so the offload schedule can be *inspected* with the
paper's emitter and counted by the executor — used in tests and the
train-overlap benchmark.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax

from repro.core import Program

__all__ = ["offload_shardings", "offloaded_optimizer", "plan_step_program",
           "attention_step_program", "host_memory_kind",
           "supports_pinned_host"]

_HOST_KIND = "pinned_host"


@functools.lru_cache(maxsize=None)
def _device_memory_kinds(device) -> tuple:
    try:
        return tuple(m.kind for m in device.addressable_memories())
    except Exception:
        return ()


def host_memory_kind(device=None) -> Optional[str]:
    """The host-side memory kind usable for offload on ``device``, or
    ``None`` when the platform has no addressable host space distinct from
    its default memory (e.g. CPU jaxlib: everything is unpinned_host)."""
    if device is None:
        device = jax.devices()[0]
    return _HOST_KIND if _HOST_KIND in _device_memory_kinds(device) else None


def supports_pinned_host(device=None) -> bool:
    return host_memory_kind(device) is not None


def _transfer_to(kind: str):
    """A placement target usable inside jit, across JAX versions."""
    space = getattr(jax, "memory", None)
    if space is not None and hasattr(space, "Space"):
        return space.Space.Host if kind == _HOST_KIND else space.Space.Device
    ttmk = getattr(jax.sharding, "TransferToMemoryKind", None)
    if ttmk is None:
        from jax._src.sharding_impls import TransferToMemoryKind as ttmk
    return ttmk(kind)


def offload_shardings(sharding_tree):
    """Move a sharding tree's memory kind to the host space; identity when
    the platform has none (the optimizer then simply stays on device)."""
    kind = host_memory_kind()
    if kind is None:
        return sharding_tree
    return jax.tree.map(
        lambda s: s.with_memory_kind(kind), sharding_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))


def _to_space(tree, kind: str):
    if host_memory_kind() is None:
        return tree     # single memory space: nothing to move
    target = _transfer_to(kind)
    return jax.tree.map(
        lambda x: jax.device_put(x, target)
        if hasattr(x, "ndim") and x.ndim > 0 else x, tree)


def offloaded_optimizer(opt):
    """Wrap an Optimizer so its state lives in host memory: the update
    streams state in (advancedload — XLA overlaps it with the backward
    pass that produces the grads) and the new state back out
    (delegatestore, overlapped with the next forward)."""
    def update(grads, state, params):
        state_dev = _to_space(state, "device")
        new_p, new_s = opt.update(grads, state_dev, params)
        return new_p, _to_space(new_s, _HOST_KIND)

    return dataclasses.replace(opt, update=update,
                               name=opt.name + "+offload")


def plan_step_program(n_steps: int = 4) -> Program:
    """A miniature training loop as a block program: host data producer,
    device fwd/bwd codelet, device optimizer update reading offloaded state,
    host metric logging — the planner hoists the batch upload (prefetch) and
    sinks the metric download (lazy fetch), exactly the schedule train.py
    implements with real arrays."""
    import numpy as np

    p = Program("train_loop")
    p.bind("w", np.zeros((64, 64), np.float32))
    p.bind("opt_m", np.zeros((64, 64), np.float32))
    p.bind("seed", np.zeros((2,), np.float32))

    p.host(lambda xp, seed: {"batch": xp.outer(seed + 1.0,
                                               xp.ones(64, xp.float32))},
           reads=("seed",), writes=("batch",), name="next_batch")
    with p.loop(n_steps):
        p.offload(lambda xp, w, batch:
                  {"grad": (w @ batch.T @ batch) / 64.0,
                   "loss": ((batch @ w) ** 2).sum(keepdims=True)[:1]},
                  reads=("w", "batch"), writes=("grad", "loss"),
                  name="fwd_bwd")
        p.offload(lambda xp, w, grad, opt_m:
                  {"w": w - 0.1 * (0.9 * opt_m + grad),
                   "opt_m": 0.9 * opt_m + grad},
                  reads=("w", "grad", "opt_m"), writes=("w", "opt_m"),
                  name="opt_update")
    p.host(lambda xp, loss: {"final_loss": loss},
           reads=("loss",), writes=("final_loss",), name="log_metrics")
    p.set_outputs("final_loss", "w")
    return p


def attention_step_program(n_steps: int = 2) -> Program:
    """A flash-attention train step as a block program with a *tagged*
    Pallas kernel block: the ``kernel="flash_attention"`` tag lets the
    plan-space tuner enumerate tile variants (``block_q``/``block_k``)
    for the attention launch and price them with the two-level roofline,
    alongside the usual policy/stream/fuse axes.  Shapes are kept small
    (S = T = 128) so interpret-mode Pallas stays fast on CPU CI while
    the clamped tile grid still yields >= 3 distinct variants."""
    import numpy as np

    from repro.kernels import ops

    B, S, T, K, G, D = 1, 128, 128, 1, 1, 8
    rng = np.random.default_rng(0)
    p = Program("attention_step")
    p.bind("q", rng.standard_normal((B, S, K, G, D)).astype(np.float32))
    p.bind("k", rng.standard_normal((B, T, K, D)).astype(np.float32))
    p.bind("v", rng.standard_normal((B, T, K, D)).astype(np.float32))
    p.bind("gain", np.ones((1,), np.float32))

    p.host(lambda xp, gain: {"g": gain * 1.001},
           reads=("gain",), writes=("g",), name="next_gain")
    with p.loop(n_steps):
        # reads are the kernel's ops-layer operands, in operand order —
        # the tuner resolves the variant grid from their shapes
        p.offload(lambda xp, q, k, v, *, block_q=128, block_k=128:
                  {"o": ops.flash_attention(q, k, v, causal=True,
                                            block_q=block_q,
                                            block_k=block_k)},
                  reads=("q", "k", "v"), writes=("o",),
                  name="attention", kernel="flash_attention")
        p.offload(lambda xp, o, g:
                  {"loss": (o * o).sum().reshape(1) * g},
                  reads=("o", "g"), writes=("loss",), name="reduce")
    p.host(lambda xp, loss: {"final_loss": loss},
           reads=("loss",), writes=("final_loss",), name="log_metrics")
    p.set_outputs("final_loss",)
    return p
