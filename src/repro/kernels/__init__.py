"""Pallas TPU kernels for the compute hot spots + jnp oracles.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), wrapped in ops.py,
validated against ref.py in tests (interpret mode on CPU)."""
from . import ops, ref

__all__ = ["ops", "ref"]
