"""RWKV-6 recurrence — chunked Pallas TPU kernel.

The per-token rank-1 state update
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t,   o_t = r_t·(S_{t-1} + u⊙k_t ⊗ v_t)
is re-expressed per chunk of L tokens as three MXU matmuls (the standard
chunked linear-attention form, adapted from the paper's CUDA kernel):

    P_t   = ∏_{s<t} w_s                (exclusive cumprod, in-chunk)
    r̃_t  = r_t ⊙ P_t ,  k̃_s = k_s / P_{s+1}
    o     = r̃ @ S₀  +  ((r̃ @ k̃ᵀ) ⊙ strict_lower + diag(r·(u⊙k))) @ v
    S_L   = diag(P_L) S₀ + (k̃ ⊙ P_L)ᵀ @ v

The chunk state S (hs × hs) persists in VMEM scratch across the sequential
chunk grid dimension.  Layouts (folded in ops.py): r,k,v,w: (BH, T, hs);
u: (BH, hs) broadcast per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sout_ref, s_ref,
                 *, block_t: int, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)       # (L, hs)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)       # decay in (0, 1)
    u = u_ref[0].astype(jnp.float32)       # (1, hs) bonus

    # exclusive cumulative product of decays (log-space for stability)
    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)         # inclusive
    p_incl = jnp.exp(cum)                  # P_{t+1} = ∏_{s<=t} w_s
    p_excl = jnp.exp(cum - logw)           # P_t     = ∏_{s<t}  w_s

    r_t = r * p_excl                       # r̃
    k_t = k / jnp.maximum(p_incl, 1e-38)   # k̃

    s0 = s_ref[...]                        # (hs, hs)
    inter = jax.lax.dot_general(
        r_t, s0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (L, hs)
    scores = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (L, L)
    L = r.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    scores = jnp.where(rows > cols, scores, 0.0)       # strict lower
    diag = (r * u * k).sum(axis=1)                     # (L,)
    intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o = inter + intra + diag[:, None] * v
    o_ref[0] = o.astype(o_ref.dtype)

    p_last = p_incl[-1]                                # (hs,)
    kp = k_t * p_last[None, :]
    s_new = p_last[:, None] * s0 + jax.lax.dot_general(
        kp, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(ti == n_t - 1)
    def _emit_state():
        sout_ref[0] = s_new


def wkv6_folded(r, k, v, w, u, *, block_t: int = 64,
                interpret: bool = False):
    """r,k,v,w: (BH, T, hs); u: (BH, hs).  Returns (o (BH,T,hs) fp32,
    final state (BH, hs, hs) fp32)."""
    BH, T, hs = r.shape
    block_t = min(block_t, T)
    assert T % block_t == 0
    n_t = T // block_t
    kernel = functools.partial(_wkv6_kernel, block_t=block_t, n_t=n_t)
    o, s_out = pl.pallas_call(
        kernel,
        grid=(BH, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, hs), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, hs), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, hs), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, hs), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, hs), lambda b, t: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, hs), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, hs, hs), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hs), jnp.float32),
            jax.ShapeDtypeStruct((BH, hs, hs), jnp.float32),
        ],
        scratch_shapes=[_VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return o, s_out
