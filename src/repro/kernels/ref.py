"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose``
ground truth in tests — naive, readable, obviously-correct)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "rglru_scan_ref", "wkv6_ref",
           "rmsnorm_ref"]


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, S, K, G, D) — NOT pre-scaled; k, v: (B, T, K, D)."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t, sequential scan (axis 1)."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, h = jax.lax.scan(step,
                        h0,
                        (jnp.moveaxis(a32, 1, 0), jnp.moveaxis(b32, 1, 0)))
    return jnp.moveaxis(h, 0, 1)


def wkv6_ref(r, k, v, w, u):
    """Sequential RWKV-6.  r,k,v,w: (BH, T, hs); u: (BH, hs).
    Returns (o fp32, final state fp32)."""
    BH, T, hs = r.shape
    s0 = jnp.zeros((BH, hs, hs), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = (x.astype(jnp.float32) for x in inp)
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bi,bij->bj", r_t,
                       s + u.astype(jnp.float32)[..., None] * kv)
        s = w_t.astype(jnp.float32)[..., None] * s + kv
        return s, o

    s, o = jax.lax.scan(step, s0, tuple(jnp.moveaxis(t, 1, 0)
                                        for t in (r, k, v, w)))
    return jnp.moveaxis(o, 0, 1), s


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w
