"""Causal/sliding-window GQA flash attention — Pallas TPU kernel.

TPU-native adaptation (vs the CUDA flash-attention the GPU world uses):
tiles are (block_q × block_k) MXU-aligned (multiples of 128 on the lane
dim), the online-softmax accumulators live in VMEM scratch and persist
across the sequential innermost grid dimension (the TPU grid is a sequential
scan over `k` blocks, not a thread block), and the GQA group dim G rides
inside the tile so K/V tiles are loaded once per q tile regardless of the
group size.

Layouts (folded in ops.py):  q: (BK, S, G, D);  k, v: (BK, T, D) where
BK = batch × kv_heads.  Output: (BK, S, G, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode runs without them
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, n_k: int, causal: bool,
                  window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (block_q, G, D)
    k = k_ref[0].astype(jnp.float32)          # (block_k, D)
    v = v_ref[0].astype(jnp.float32)          # (block_k, D)

    s = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (block_q, G, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1, block_k), 2)
    mask = jnp.ones((block_q, 1, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (block_q, G)
    m_new = jnp.maximum(m_prev, s.max(axis=2))
    p = jnp.exp(s - m_new[..., None])          # (block_q, G, block_k)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=2)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (block_q, G, D)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        lse = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / lse[..., None]).astype(o_ref.dtype)


def flash_attention_folded(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (BK, S, G, D) pre-scaled by 1/sqrt(D); k, v: (BK, T, D)."""
    BK, S, G, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    n_q, n_k = S // block_q, T // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
        causal=causal, window=window)

    # fp32 accumulators in VMEM, persisting across the sequential k grid dim
    scratch_shapes = [
        _VMEM((block_q, G, D), jnp.float32),
        _VMEM((block_q, G), jnp.float32),
        _VMEM((block_q, G), jnp.float32),
    ]

    return pl.pallas_call(
        kernel,
        grid=(BK, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, G, D), lambda b, qi, ki: (b, qi, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, G, D),
                               lambda b, qi, ki: (b, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, S, G, D), q.dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(q, k, v)
