"""Jit'd model-facing wrappers for the Pallas kernels.

These fold the model layouts into the kernel layouts, pick block sizes, and
choose interpret mode automatically (CPU backend ⇒ interpret=True, so the
same model code validates on this container and compiles natively on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import rglru_scan as _rg
from . import rmsnorm as _rn
from . import wkv6 as _wkv

__all__ = ["flash_attention", "rglru_scan", "wkv6", "rmsnorm",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention_vjp(q, k, v, causal, window, block_q, block_k,
                         interpret):
    B, S, K, G, D = q.shape
    T = k.shape[1]
    scale = 1.0 / (D ** 0.5)
    qf = (q * scale).transpose(0, 2, 1, 3, 4).reshape(B * K, S, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, T, D)
    o = _fa.flash_attention_folded(qf, kf, vf, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    return o.reshape(B, K, S, G, D).transpose(0, 2, 1, 3, 4)


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out = _flash_attention_vjp(q, k, v, causal, window, block_q, block_k,
                               interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, g):
    """Backward through the memory-efficient blockwise formulation (on real
    TPU a dedicated bwd kernel would slot in here; numerics are identical —
    validated in tests)."""
    from repro.models.attention import blockwise_attention
    q, k, v = res

    def f(q, k, v):
        return blockwise_attention(q, k, v, causal=causal, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_flash_attention_vjp.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """q: (B, S, K, G, D); k, v: (B, T, K, D) → (B, S, K, G, D).
    Differentiable: Pallas forward + blockwise online-softmax backward."""
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention_vjp(q, k, v, causal, window, block_q, block_k,
                                interpret)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rglru_scan(a, b, *, block_t: int = 256, interpret: bool = None):
    """a, b: (B, T, D) → h (B, T, D) fp32."""
    if interpret is None:
        interpret = default_interpret()
    return _rg.rglru_scan(a, b, block_t=block_t, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6(r, k, v, w, u, *, block_t: int = 64, interpret: bool = None):
    """r,k,v,w: (B, T, H, hs); u: (H, hs).
    Returns (o (B,T,H,hs) fp32, state (B,H,hs,hs) fp32)."""
    if interpret is None:
        interpret = default_interpret()
    B, T, H, hs = r.shape
    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, T, hs)
    uu = jnp.broadcast_to(u[None], (B, H, hs)).reshape(B * H, hs)
    o, s = _wkv.wkv6_folded(fold(r), fold(k), fold(v), fold(w), uu,
                            block_t=block_t, interpret=interpret)
    o = o.reshape(B, H, T, hs).transpose(0, 2, 1, 3)
    return o, s.reshape(B, H, hs, hs)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = None):
    """x: (..., D); w: (D,)."""
    if interpret is None:
        interpret = default_interpret()
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    n = xf.shape[0]
    br = block_rows
    while n % br:
        br //= 2
    o = _rn.rmsnorm(xf, w, eps=eps, block_rows=max(br, 1),
                    interpret=interpret)
    return o.reshape(shape)
