"""Fused RMSNorm row kernel — Pallas TPU.

Trivial but hot (runs 2× per layer): fuses the fp32 mean-square reduction,
rsqrt, cast and scale into one VMEM pass over (block_rows, D) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype)
                  * w_ref[...])


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (N, D); w: (D,)."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, w)
