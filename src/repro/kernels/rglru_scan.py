"""RG-LRU diagonal linear recurrence  h_t = a_t ⊙ h_{t-1} + b_t — Pallas.

TPU adaptation: the time axis is chunked over a sequential grid dimension;
the cross-chunk carry h lives in VMEM scratch (persists across grid steps),
and the in-chunk inclusive scan is a Hillis-Steele doubling network
(log₂(block_t) vector steps on (block_t, D) tiles — VPU-friendly, no
sequential loop over tokens).

Layouts: a, b: (B, T, D) fp32 → out h: (B, T, D) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _scan_block(a, b):
    """Inclusive scan of h_t = a_t h_{t-1} + b_t within a (T, D) block via
    Hillis-Steele doubling: log2(T) steps."""
    T = a.shape[0]
    off = 1
    while off < T:
        a_sh = jnp.pad(a, ((off, 0), (0, 0)), constant_values=1.0)[:T]
        b_sh = jnp.pad(b, ((off, 0), (0, 0)))[:T]
        b = a * b_sh + b
        a = a * a_sh
        off *= 2
    return a, b      # a = cumulative products, b = scanned h


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                       # (block_t, D) fp32
    b = b_ref[0]
    prod, h = _scan_block(a, b)
    h = h + prod * h_ref[...]          # fold in carry from previous chunk
    o_ref[0] = h
    h_ref[...] = h[-1:]                # (1, D) carry


def rglru_scan(a, b, *, block_t: int = 256, interpret: bool = False):
    """a, b: (B, T, D) fp32; returns inclusive scan h (B, T, D) fp32."""
    B, T, D = a.shape
    block_t = min(block_t, T)
    assert T % block_t == 0
    n_t = T // block_t
    kernel = functools.partial(_rglru_kernel, n_t=n_t)
    return pl.pallas_call(
        kernel,
        grid=(B, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, D), lambda b_, t: (b_, t, 0)),
            pl.BlockSpec((1, block_t, D), lambda b_, t: (b_, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, D), lambda b_, t: (b_, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        scratch_shapes=[_VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
