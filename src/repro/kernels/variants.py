"""Enumerable tile/block variant grids for the Pallas kernels (ISSUE 6).

Each kernel exposes a small grid of lane-aligned tile sizes plus the three
hooks the kernel axis of the plan-space tuner needs:

  * ``validate(shapes, params)`` — mirror the kernel's own clamping
    (``block = min(block, axis)``) and divisibility asserts, returning the
    *canonical* (clamped) parameter dict or ``None`` when the tile shape is
    invalid for these operand shapes.  Canonicalisation is what lets
    dominance pruning merge declared variants that collapse onto the same
    launched tile (e.g. ``block_q=256`` on a 128-token sequence).
  * ``roofline(shapes, itemsizes, params)`` — analytic (flops, HBM bytes)
    for one full sweep of the kernel grid, the per-kernel cutout consumed by
    ``roofline.analysis.kernel_roofline_terms``.  Bytes follow the tile
    revisit structure (e.g. flash attention re-reads K/V once per q tile),
    so ``kernel_s`` genuinely differs across variants.
  * the operand-shape convention: a kernel-tagged block's declared reads
    are, in order, the kernel's array operands at the *ops layer* layout
    (``flash_attention``: q (B,S,K,G,D), k, v (B,T,K,D); ``wkv6``: r, k, v,
    w (B,T,H,hs), u (H,hs); ``rglru_scan``: a, b (B,T,D); ``rmsnorm``:
    x (..., D), w (D,)).

This module is imported by the tuner/roofline layer and therefore stays
stdlib-only — no jax, no numpy (``repro.kernels.__init__`` pulls jax, so
consumers import ``repro.kernels.variants`` directly).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["KernelVariant", "KERNELS", "kernel_names", "variants_for",
           "default_variant", "validate_variant", "kernel_roofline",
           "kernel_workset", "bind_variant"]

Params = Dict[str, int]
ParamsKey = Tuple[Tuple[str, int], ...]


def _key(params: Params) -> ParamsKey:
    return tuple(sorted(params.items()))


@dataclasses.dataclass(frozen=True)
class KernelVariant:
    """One point of a kernel's tile grid: ``params`` is the canonical
    sorted ``((name, value), ...)`` tuple — hashable, JSON-friendly, and
    the unit dominance pruning keys on."""
    kernel: str
    params: ParamsKey

    @property
    def label(self) -> str:
        inner = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.kernel}[{inner}]"

    def kwargs(self) -> Params:
        return dict(self.params)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _clamp_div(block: int, axis: int) -> Optional[int]:
    """The kernels' shared tile rule: clamp to the axis, then require the
    axis to divide evenly."""
    block = min(int(block), int(axis))
    if block <= 0 or axis % block:
        return None
    return block


# --- flash_attention: q (B, S, K, G, D); k, v (B, T, K, D) ----------------

def _flash_validate(shapes, params) -> Optional[Params]:
    (B, S, K, G, D) = shapes[0]
    T = shapes[1][1]
    bq = _clamp_div(params["block_q"], S)
    bk = _clamp_div(params["block_k"], T)
    if bq is None or bk is None:
        return None
    return {"block_q": bq, "block_k": bk}


def _flash_workset(shapes, itemsizes, params):
    (B, S, K, G, D) = shapes[0]
    eb = itemsizes[0]
    bq, bk = params["block_q"], params["block_k"]
    # one program instance's VMEM tiles: q + fp32 o/accumulators, the
    # current k/v tile pair, and the fp32 score tile
    return float(bq * G * D * (eb + 4) + 2 * bk * D * eb + bq * G * bk * 4)


def _flash_roofline(shapes, itemsizes, params):
    (B, S, K, G, D) = shapes[0]
    T = shapes[1][1]
    eb_q, eb_k, eb_v = itemsizes[:3]
    # two MXU dots per (q, k) tile pair: s = q·kᵀ and p·v, 2·bq·G·bk·D each
    flops = 4.0 * B * K * S * G * T * D
    n_q = S // params["block_q"]
    # q + o stream once; every q tile re-sweeps the whole K/V sequence
    q_bytes = B * K * S * G * D
    kv_bytes = B * K * T * D
    bytes_ = (q_bytes * (eb_q + eb_q)
              + n_q * kv_bytes * (eb_k + eb_v))
    return flops, float(bytes_)


# --- wkv6: r, k, v, w (B, T, H, hs); u (H, hs) ----------------------------

def _wkv6_validate(shapes, params) -> Optional[Params]:
    T = shapes[0][1]
    bt = _clamp_div(params["block_t"], T)
    if bt is None:
        return None
    return {"block_t": bt}


def _wkv6_workset(shapes, itemsizes, params):
    (B, T, H, hs) = shapes[0]
    eb = itemsizes[0]
    L = params["block_t"]
    # r/k/v/w chunk tiles + u + fp32 running state + fp32 score tile + o
    return float(4 * L * hs * eb + hs * eb + hs * hs * 4
                 + L * L * 4 + L * hs * 4)


def _wkv6_roofline(shapes, itemsizes, params):
    (B, T, H, hs) = shapes[0]
    L = params["block_t"]
    n_t = T // L
    # four MXU dots per chunk: inter (L·hs²), scores (L²·hs), intra (L²·hs),
    # state update (L·hs²) — ×2 flops each, summed over B·H·n_t chunks
    flops = 2.0 * B * H * (2 * T * hs * hs + 2 * T * L * hs)
    eb = itemsizes[0]
    io = B * T * H * hs
    bytes_ = (4 * io * eb            # r, k, v, w read once
              + io * 4               # o written fp32
              + B * H * hs * hs * 4  # final state out fp32
              + n_t * B * H * hs * eb)   # u re-read per chunk
    return flops, float(bytes_)


# --- rglru_scan: a, b (B, T, D) -------------------------------------------

def _rglru_validate(shapes, params) -> Optional[Params]:
    T = shapes[0][1]
    bt = _clamp_div(params["block_t"], T)
    if bt is None:
        return None
    return {"block_t": bt}


def _rglru_workset(shapes, itemsizes, params):
    (B, T, D) = shapes[0]
    L = params["block_t"]
    # a/b chunk tiles in, h chunk out + fp32 carry row, all fp32
    return float(3 * L * D * 4 + D * 4)


def _rglru_roofline(shapes, itemsizes, params):
    (B, T, D) = shapes[0]
    L = params["block_t"]
    # Hillis-Steele doubling: ceil(log2 L) steps × 3 VPU flops per element
    steps = max(1, math.ceil(math.log2(L))) if L > 1 else 1
    flops = 3.0 * B * T * D * steps
    bytes_ = 3 * B * T * D * 4       # a, b in + h out, all fp32
    return flops, float(bytes_)


# --- rmsnorm: x (..., D); w (D,) ------------------------------------------

def _rmsnorm_canon_rows(block_rows: int, n: int) -> int:
    # mirror ops.rmsnorm: clamp, then halve until the row count divides
    br = min(int(block_rows), int(n))
    while br > 1 and n % br:
        br //= 2
    return max(br, 1)


def _rmsnorm_validate(shapes, params) -> Optional[Params]:
    x = shapes[0]
    n = _prod(x[:-1])
    return {"block_rows": _rmsnorm_canon_rows(params["block_rows"], n)}


def _rmsnorm_workset(shapes, itemsizes, params):
    x = shapes[0]
    D = x[-1]
    eb = itemsizes[0]
    br = params["block_rows"]
    # the row tile in/out + the gain vector
    return float(2 * br * D * eb + D * eb)


def _rmsnorm_roofline(shapes, itemsizes, params):
    x = shapes[0]
    D = x[-1]
    n = _prod(x[:-1])
    flops = 3.0 * n * D              # square-reduce, rsqrt-scale, gain
    eb = itemsizes[0]
    n_blocks = n // params["block_rows"]
    bytes_ = (2 * n * D * eb         # x in, o out
              + n_blocks * D * eb)   # w re-read per row tile
    return flops, float(bytes_)


KERNELS: Dict[str, dict] = {
    "flash_attention": {
        "grid": {"block_q": (64, 128, 256), "block_k": (64, 128, 256)},
        "defaults": {"block_q": 128, "block_k": 128},
        "validate": _flash_validate,
        "roofline": _flash_roofline,
        "workset": _flash_workset,
    },
    "wkv6": {
        # 128 is deliberately absent: the chunk form divides k by the
        # in-chunk decay cumprod, which overflows fp32 once the chunk is
        # long enough for strong decays (w ~ 0.2 over 128 steps)
        "grid": {"block_t": (16, 32, 64)},
        "defaults": {"block_t": 64},
        "validate": _wkv6_validate,
        "roofline": _wkv6_roofline,
        "workset": _wkv6_workset,
    },
    "rglru_scan": {
        "grid": {"block_t": (64, 128, 256)},
        "defaults": {"block_t": 256},
        "validate": _rglru_validate,
        "roofline": _rglru_roofline,
        "workset": _rglru_workset,
    },
    "rmsnorm": {
        "grid": {"block_rows": (64, 128, 256, 512)},
        "defaults": {"block_rows": 256},
        "validate": _rmsnorm_validate,
        "roofline": _rmsnorm_roofline,
        "workset": _rmsnorm_workset,
    },
}


def kernel_names() -> Tuple[str, ...]:
    return tuple(KERNELS)


def validate_variant(kernel: str, shapes: Sequence[tuple],
                     params: Params) -> Optional[KernelVariant]:
    """Canonical variant for ``params`` on these operand shapes, or ``None``
    when the tile shape is invalid (non-dividing after clamping)."""
    canon = KERNELS[kernel]["validate"](tuple(map(tuple, shapes)), params)
    if canon is None:
        return None
    return KernelVariant(kernel, _key(canon))


def variants_for(kernel: str, shapes: Sequence[tuple],
                 itemsizes: Sequence[int] = ()) -> Tuple[KernelVariant, ...]:
    """All *distinct* valid variants of ``kernel`` for these operand
    shapes: the declared grid, shape-validity filtered, canonicalised and
    deduped (clamping can fold several declared tiles onto one launch)."""
    spec = KERNELS[kernel]
    names = tuple(spec["grid"])
    seen, out = set(), []
    for combo in itertools.product(*(spec["grid"][n] for n in names)):
        v = validate_variant(kernel, shapes, dict(zip(names, combo)))
        if v is not None and v.params not in seen:
            seen.add(v.params)
            out.append(v)
    return tuple(out)


def default_variant(kernel: str) -> KernelVariant:
    return KernelVariant(kernel, _key(KERNELS[kernel]["defaults"]))


def kernel_roofline(kernel: str, params: Params, shapes: Sequence[tuple],
                    itemsizes: Sequence[int] = ()) -> Tuple[float, float]:
    """(flops, HBM bytes) for one grid sweep of ``kernel`` launched with
    ``params`` on these operand shapes."""
    shapes = tuple(map(tuple, shapes))
    if not itemsizes:
        itemsizes = (4,) * len(shapes)
    canon = KERNELS[kernel]["validate"](shapes, dict(params))
    if canon is None:
        raise ValueError(
            f"invalid {kernel} tile {dict(params)} for shapes {shapes}")
    return KERNELS[kernel]["roofline"](shapes, tuple(itemsizes), canon)


def kernel_workset(kernel: str, params: Params, shapes: Sequence[tuple],
                   itemsizes: Sequence[int] = ()) -> float:
    """On-chip working-set bytes of one program instance of ``kernel``
    launched with ``params`` — the tile buffers a single grid step holds
    live (ISSUE 10: the kernel-variant term of the plan peak-memory
    walk, ``repro.core.residency.plan_peak_device_bytes``).  Larger
    tiles buy roofline time at the price of residency, which is exactly
    the time × memory trade-off the Pareto tuner surfaces."""
    shapes = tuple(map(tuple, shapes))
    if not itemsizes:
        itemsizes = (4,) * len(shapes)
    canon = KERNELS[kernel]["validate"](shapes, dict(params))
    if canon is None:
        raise ValueError(
            f"invalid {kernel} tile {dict(params)} for shapes {shapes}")
    return KERNELS[kernel]["workset"](shapes, tuple(itemsizes), canon)


@functools.lru_cache(maxsize=None)
def bind_variant(fn, params: ParamsKey):
    """A *memoized* partial binding of a kernel block fn to its variant
    kwargs.  Memoization keeps the bound callable's identity stable across
    calls so backend jit caches (keyed on fn identity) still hit."""
    return functools.partial(fn, **dict(params))
