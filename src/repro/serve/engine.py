"""Continuous-batching serving engine: request-level plan executor.

The engine runs ONE device-resident decode batch of fixed capacity and
streams requests through it:

    arrivals ─▶ AdmissionQueue ─▶ prefill (stream 1, shape-bucketed)
                                      │ insert row (donated scatter)
                                      ▼
                   ┌──────── decode batch (capacity C) ────────┐
                   │  every step: ONE jitted decode over all C │
                   │  rows; finished rows retire at boundaries │
                   └───────────────┬───────────────────────────┘
                                   ▼
                  lazy batched token download ─▶ slot recycled

Residency follows the paper end to end: weights are uploaded once
through ``DeviceResidency`` and never move again (noupdate); admission
uploads only the request's prompt (advancedload — the single bulk input
it owns); the decode loop carries tokens/positions/output buffer ON
DEVICE, so steady-state host↔device traffic is zero; generated tokens
come back in one batched fetch per retirement flush (delegatestore).

Shape buckets & the plan cache: prompts are right-padded to power-of-two
buckets (exact lengths for recurrent archs, where padding would corrupt
the carried state) so repeated traffic reuses a handful of compiled
prefill shapes.  Each bucket maps onto a persistent ``TuneCache`` entry
keyed by (cfg, backend fingerprint, bucket dims): the first time a
bucket is seen across ALL processes it is measured once (blocking), and
every later run — including fresh engines in fresh processes — looks it
up and stays on the pure async path with zero online measurements.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

from .batcher import ContinuousBatcher
from .kvpool import KVSlotPool, cache_bytes_per_slot
from .queue import AdmissionQueue
from .request import Request, RequestState

__all__ = ["ServeRuntime", "Engine", "derive_capacity", "bucket_len"]


def bucket_len(prompt_len: int, max_seq: int, *, exact: bool) -> int:
    """Padded prompt length for a shape bucket: next power of two (min 8),
    capped at ``max_seq``.  ``exact`` archs (recurrent state) get their
    true length — padding would pollute the carried state."""
    if exact:
        return prompt_len
    return min(max(8, 1 << (prompt_len - 1).bit_length()), max_seq)


def derive_capacity(model, max_seq: int, device_bytes: int,
                    weights_bytes: int) -> int:
    """Decode-batch capacity from a device-bytes budget: whatever is left
    after resident weights, divided by one slot's cache footprint."""
    per_slot = cache_bytes_per_slot(model, max_seq)
    return max(1, (device_bytes - weights_bytes) // max(per_slot, 1))


class ServeRuntime:
    """Compiled machinery shared by engines (and by benchmark modes, so
    continuous-vs-static comparisons never pay a recompile): resident
    params, the bucketed prefill jit, the whole-batch decode jit, the
    admission row-write jit, and the bucket↔tunecache bookkeeping."""

    def __init__(self, cfg, *, max_seq: int, backend: Any = None,
                 params: Any = None, seed: int = 0, use_pallas: bool = False):
        import jax
        import jax.numpy as jnp

        from repro.core.backend import get_backend
        from repro.core.residency import DeviceResidency
        from repro.core.tunecache import default_cache
        from repro.models import Transformer

        self.cfg = cfg
        self.max_seq = int(max_seq)
        be = get_backend(backend)
        # two logical streams: 0 = decode compute, 1 = prefill + fetches
        self.be = be.variant(n_streams=max(be.n_streams, 2))
        self.model = Transformer(cfg, use_pallas=use_pallas)
        self.exact_buckets = cfg.layer_pattern in ("rwkv", "griffin")

        # weights resident once, through the instrumented residency layer
        if params is None:
            params = self.model.init(jax.random.key(seed))
        self.residency = DeviceResidency(backend=self.be)
        leaves, treedef = jax.tree.flatten(params)
        for i, leaf in enumerate(leaves):
            self.residency.put_host(f"w{i:04d}", np.asarray(leaf))
        for i in range(len(leaves)):
            self.residency.prefetch(f"w{i:04d}")   # advancedload, async
        self.params = jax.tree.unflatten(
            treedef, [self.residency.device_value(f"w{i:04d}")
                      for i in range(len(leaves))])
        self.weights_bytes = self.residency.stats.h2d_bytes

        self._prefill = jax.jit(
            lambda p, b, lp: self.model.prefill(
                p, b, max_seq=self.max_seq, last_pos=lp))
        self._decode = jax.jit(self._decode_impl,
                               donate_argnums=(1, 2, 3, 4, 5))
        self._admit = jax.jit(self._admit_impl, donate_argnums=(1, 2, 3, 4))
        # park a finished row's tokens device-side so its slot can be
        # reused WITHOUT a host sync; everything downloads in one batch
        self._park = jax.jit(
            lambda park, out, slot, idx: park.at[idx].set(out[slot]),
            donate_argnums=(0,))
        self._jnp = jnp

        # bucket -> "measured" | "cached"; persisted across processes via
        # the tune cache (None when REPRO_TUNE_CACHE is unset)
        self.tune = default_cache()
        self._buckets: Dict[int, str] = {}
        self.tune_measurements = 0
        self.tune_hits = 0

    # -- jitted bodies -------------------------------------------------------
    def _decode_impl(self, params, cache, tok, pos, out_buf, gen_idx):
        """One step for the WHOLE padded batch.  Inactive rows are stepped
        too (their writes land past their read window or are dropped at
        gen_idx == gen_cap); their cache rows are dead until the donated
        insert overwrites them at the next admission."""
        jnp = self._jnp
        C, gen_cap = out_buf.shape
        if self.cfg.input_embeds:
            step_in = {"embeds": jnp.zeros((C, self.cfg.d_model),
                                           jnp.float32)}
        else:
            step_in = {"tokens": tok}
        logits, cache = self.model.decode_step(params, cache, step_in, pos)
        if self.cfg.n_codebooks:
            logits = logits[..., 0, :]
        ntok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_buf = out_buf.at[jnp.arange(C), gen_idx].set(ntok, mode="drop")
        gen_idx = jnp.where(gen_idx < gen_cap, gen_idx + 1, gen_idx)
        return ntok, pos + 1, out_buf, gen_idx, cache

    def _admit_impl(self, logits, tok, pos, out_buf, gen_idx, slot, p0):
        """Write one admitted row's metadata: first sampled token (argmax
        of the prefill's real-last-token logits, computed device-side — no
        host sync at admission), next decode position, output cursor."""
        jnp = self._jnp
        lg = logits[0]
        if self.cfg.n_codebooks:
            lg = lg[0]
        t0 = jnp.argmax(lg).astype(jnp.int32)
        tok = tok.at[slot].set(t0)
        pos = pos.at[slot].set(p0)
        out_buf = out_buf.at[slot, 0].set(t0)
        gen_idx = gen_idx.at[slot].set(1)
        return tok, pos, out_buf, gen_idx

    # -- bucketed prefill ----------------------------------------------------
    def bucket_of(self, prompt_len: int) -> int:
        return bucket_len(prompt_len, self.max_seq,
                          exact=self.exact_buckets)

    def _bucket_fingerprint(self, padded: int) -> str:
        from repro.core.tunecache import (COST_MODEL_VERSION, _sha,
                                          backend_fingerprint)
        return _sha({
            "cost_model_version": COST_MODEL_VERSION,
            "cfg": dataclasses.asdict(self.cfg),
            "backend": backend_fingerprint(self.be),
            "bucket": {"padded_len": padded, "max_seq": self.max_seq},
        })

    def prefill_request(self, req: Request):
        """Pad to the request's bucket, run the prefill on logical stream 1,
        and return (last-real-token logits, cache tree).  Cold buckets are
        measured once (blocking) and stored in the persistent tune cache;
        warm buckets stay fully asynchronous."""
        import jax
        jnp = self._jnp
        cfg, L = self.cfg, req.prompt_len
        padded = self.bucket_of(L)
        if cfg.input_embeds:
            buf = np.zeros((1, padded, cfg.d_model), np.float32)
            buf[0, :L] = req.prompt
            batch = {"embeds": jnp.asarray(buf)}
        else:
            buf = np.zeros((1, padded), np.int32)
            buf[0, :L] = req.prompt
            batch = {"tokens": jnp.asarray(buf)}
        last_pos = jnp.asarray([L - 1], jnp.int32)

        state = self._buckets.get(padded)
        if state is None:
            slot = f"serve--{cfg.name}--p{padded}"
            fp = self._bucket_fingerprint(padded)
            hit = self.tune.lookup(slot, fp) if self.tune else None
            if hit is not None:
                self._buckets[padded] = "cached"
                self.tune_hits += 1
            else:
                t0 = time.perf_counter()
                logits, cache = self._prefill(self.params, batch, last_pos)
                jax.block_until_ready(logits)
                ms = (time.perf_counter() - t0) * 1e3
                self.tune_measurements += 1
                self._buckets[padded] = "measured"
                if self.tune:
                    self.tune.store(slot, fp, {"prefill_ms": ms,
                                               "padded_len": padded})
                return self.be.track(logits, stream=1), cache
        else:
            self.tune_hits += 1
        logits, cache = self._prefill(self.params, batch, last_pos)
        return self.be.track(logits, stream=1), cache


class Engine:
    """The driver loop: admission, continuous decode, lazy retirement."""

    def __init__(self, runtime: ServeRuntime, *, capacity: int,
                 join_policy: str = "continuous", policy: str = "fcfs",
                 max_batch_tokens: Optional[int] = None):
        self.rt = runtime
        self.capacity = int(capacity)
        if max_batch_tokens is None:
            max_batch_tokens = self.capacity * runtime.max_seq
        self.pool = KVSlotPool(runtime.model, self.capacity, runtime.max_seq)
        self.queue = AdmissionQueue(policy, max_batch_tokens)
        self.batcher = ContinuousBatcher(join_policy)
        self.completed: List[Request] = []
        self.fetch_batches = 0

    # -- internals -----------------------------------------------------------
    def _admit_one(self, req: Request, now: float) -> None:
        req.to_prefilling(now)
        slot = self.pool.alloc()
        assert slot is not None   # pop_admissible was bounded by free_count
        logits, cache = self.rt.prefill_request(req)
        self.pool.insert(cache, 0, slot)
        self._tok, self._pos, self._out, self._gidx = self.rt._admit(
            logits, self._tok, self._pos, self._out, self._gidx,
            slot, req.prompt_len)
        req.to_decoding(slot, now)
        self.batcher.join(req, slot)

    def _finish(self, slot: int, now: float) -> None:
        """Retire a row at a step boundary: copy its tokens into the park
        buffer DEVICE-SIDE (async, no sync) and recycle the slot at once —
        the host never waits on a finished request mid-run."""
        req = self.batcher.leave(slot)
        req.to_finished(now)
        idx = self._n_fetched + len(self._parked)
        self._park_buf = self.rt._park(self._park_buf, self._out, slot, idx)
        self._parked.append(req)
        self.pool.free(slot)

    def _flush_retired(self) -> None:
        """delegatestore: ONE download covers every request finished since
        the last flush."""
        if not self._parked:
            return
        buf = self.rt.be.download(self._park_buf, stream=1)
        self.fetch_batches += 1
        for idx, req in enumerate(self._parked, start=self._n_fetched):
            req.retire(np.asarray(buf[idx, :req.max_new_tokens]))
            self.completed.append(req)
        self._n_fetched += len(self._parked)
        self._parked = []

    # -- driver --------------------------------------------------------------
    def run(self, requests: List[Request], *,
            respect_arrivals: bool = True) -> Dict[str, Any]:
        import jax.numpy as jnp
        rt, cfg = self.rt, self.rt.cfg
        for r in requests:
            want = 2 if cfg.input_embeds else 1
            if r.prompt.ndim != want:
                raise ValueError(
                    f"request {r.rid}: prompt ndim {r.prompt.ndim} for "
                    f"{'embeds' if cfg.input_embeds else 'token'} arch")
            if r.total_tokens > rt.max_seq:
                raise ValueError(
                    f"request {r.rid}: prompt+gen {r.total_tokens} exceeds "
                    f"max_seq {rt.max_seq}")
            if (self.queue.max_batch_tokens > 0
                    and r.total_tokens > self.queue.max_batch_tokens):
                raise ValueError(
                    f"request {r.rid}: {r.total_tokens} tokens can never "
                    f"fit the batch budget {self.queue.max_batch_tokens}")
        if not requests:
            self._parked, self._n_fetched = [], 0
            return self._report(0.0)

        C = self.capacity
        gen_cap = max(r.max_new_tokens for r in requests)
        self._tok = jnp.zeros((C,), jnp.int32)
        self._pos = jnp.zeros((C,), jnp.int32)
        self._out = jnp.zeros((C, gen_cap), jnp.int32)
        # gen_idx == gen_cap ⇒ row inactive: its writes drop out of bounds
        self._gidx = jnp.full((C,), gen_cap, jnp.int32)
        self._park_buf = jnp.zeros((len(requests), gen_cap), jnp.int32)
        self._parked: List[Request] = []
        self._n_fetched = 0

        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        i, t0 = 0, time.perf_counter()
        while i < len(pending) or len(self.queue) or self.batcher.active:
            now = time.perf_counter() - t0
            while i < len(pending) and (not respect_arrivals
                                        or pending[i].arrival_s <= now):
                self.queue.push(pending[i])
                i += 1

            if (len(self.queue) and self.batcher.can_join()
                    and self.pool.free_count > 0):
                for req in self.queue.pop_admissible(
                        self.pool.free_count, self.batcher.tokens_in_flight):
                    self._admit_one(req, time.perf_counter() - t0)
                now = time.perf_counter() - t0
                for slot in self.batcher.finished_now():   # gen == 1
                    self._finish(slot, now)

            if self.batcher.active:
                (self._tok, self._pos, self._out, self._gidx,
                 self.pool.cache) = rt._decode(
                    rt.params, self.pool.cache, self._tok, self._pos,
                    self._out, self._gidx)
                done = self.batcher.step()
                if done:
                    now = time.perf_counter() - t0
                    for slot in done:
                        self._finish(slot, now)
            elif i < len(pending) and not len(self.queue):
                time.sleep(2e-4)   # idle: next arrival not due yet

        self._flush_retired()   # delegatestore: one download for everything
        wall = time.perf_counter() - t0
        self.pool.assert_no_leaks()
        return self._report(wall)

    def _report(self, wall: float) -> Dict[str, Any]:
        done = self.completed
        assert all(r.state is RequestState.FINISHED for r in done)
        lat = np.array([r.latency_s for r in done]) if done else np.array([])
        ttft = np.array([r.t_first_token - r.arrival_s for r in done
                         if r.t_first_token is not None])
        gen_tokens = sum(r.max_new_tokens for r in done)
        rt = self.rt
        return {
            "n_requests": len(done),
            "dropped": 0,
            "wall_s": wall,
            "requests_per_s": len(done) / max(wall, 1e-9),
            "tokens_per_s": gen_tokens / max(wall, 1e-9),
            "gen_tokens": gen_tokens,
            "latency_p50_s": float(np.percentile(lat, 50)) if len(lat)
            else float("nan"),
            "latency_p99_s": float(np.percentile(lat, 99)) if len(lat)
            else float("nan"),
            "ttft_p50_s": float(np.percentile(ttft, 50)) if len(ttft)
            else float("nan"),
            "steps": self.batcher.steps,
            "occupancy": self.batcher.occupancy(self.capacity),
            "join_policy": self.batcher.join_policy,
            "capacity": self.capacity,
            "fetch_batches": self.fetch_batches,
            "queue": self.queue.stats(),
            "pool": self.pool.stats(),
            "tune": {
                "measurements": rt.tune_measurements,
                "hits": rt.tune_hits,
                "buckets": dict(rt._buckets),
                "persistent": rt.tune is not None,
            },
            "residency": {
                "weights_h2d_bytes": rt.weights_bytes,
                "h2d_transfers": rt.residency.stats.h2d_transfers,
                "elided": rt.residency.stats.elided,
            },
        }
