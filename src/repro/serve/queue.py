"""Admission queue for the serving engine.

Holds arrived-but-not-yet-admitted requests and decides, at each step
boundary, which of them may join the running batch.  Two ordering
policies:

``fcfs``
    strict arrival order — the latency-fairness baseline;
``sjf``
    shortest-prompt-first — admits cheap prefills ahead of long ones,
    trading worst-case fairness for decode-batch occupancy (the classic
    serving throughput lever).

Admission is bounded by TWO resources, both supplied by the engine:

* free KV-cache slots (one per request, from ``kvpool``), and
* a **max-batch-tokens budget**: the sum of ``prompt + max_new_tokens``
  over every in-flight request must stay under a token budget the
  engine derives from device-bytes accounting (weight residency bytes
  measured by ``core.residency.DeviceResidency`` + per-token cache
  bytes from ``init_cache`` shapes — see ``engine.derive_capacity``).

A request that does not fit WAITS — it is never dropped and never
OOMs the pool; ``stats()`` reports peak depth so saturation is visible.
"""
from __future__ import annotations

from typing import List

from .request import Request

__all__ = ["AdmissionQueue", "POLICIES"]

POLICIES = ("fcfs", "sjf")


class AdmissionQueue:
    def __init__(self, policy: str = "fcfs", max_batch_tokens: int = 0):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown admission policy {policy!r}; have {POLICIES}")
        self.policy = policy
        # <= 0 disables the token budget (slots remain the only bound)
        self.max_batch_tokens = int(max_batch_tokens)
        self._items: List[Request] = []
        self._arrived = 0
        self._peak_depth = 0

    def __len__(self) -> int:
        return len(self._items)

    def push(self, req: Request) -> None:
        self._items.append(req)
        self._arrived += 1
        self._peak_depth = max(self._peak_depth, len(self._items))

    def _ordered(self) -> List[Request]:
        if self.policy == "sjf":
            # stable: equal prompt lengths keep arrival order
            return sorted(self._items, key=lambda r: r.prompt_len)
        return list(self._items)

    def pop_admissible(self, free_slots: int,
                       tokens_in_flight: int) -> List[Request]:
        """Remove and return the requests that may be admitted now:
        policy order, one slot each, and (when a budget is set) keeping
        ``tokens_in_flight + sum(total_tokens)`` under the budget.  A
        budget-blocked request blocks everything behind it in policy
        order — admission stays an ordered queue, not a knapsack."""
        admitted: List[Request] = []
        budget = tokens_in_flight
        for req in self._ordered():
            if len(admitted) >= free_slots:
                break
            if (self.max_batch_tokens > 0
                    and budget + req.total_tokens > self.max_batch_tokens):
                break
            admitted.append(req)
            budget += req.total_tokens
        for req in admitted:
            self._items.remove(req)
        return admitted

    def stats(self) -> dict:
        return {
            "policy": self.policy,
            "max_batch_tokens": self.max_batch_tokens,
            "arrived": self._arrived,
            "depth": len(self._items),
            "peak_depth": self._peak_depth,
        }
