"""Continuous-batching serving engine (request-level plan executor).

``request``/``queue`` hold the host-side lifecycle and admission policy,
``kvpool`` the fixed-capacity device slot allocator, ``batcher`` the
decode-batch occupancy bookkeeping, ``engine`` the driver loop with
shape-bucketed prefills mapped onto the persistent tune cache, and
``load`` the seeded open-loop trace generator the benchmark replays.
"""
from .batcher import JOIN_POLICIES, ContinuousBatcher
from .engine import Engine, ServeRuntime, bucket_len, derive_capacity
from .kvpool import KVSlotPool, cache_bytes_per_slot, infer_batch_axes
from .load import make_trace
from .queue import POLICIES, AdmissionQueue
from .request import Request, RequestState

__all__ = [
    "Request", "RequestState", "AdmissionQueue", "POLICIES",
    "ContinuousBatcher", "JOIN_POLICIES", "KVSlotPool", "infer_batch_axes",
    "cache_bytes_per_slot", "ServeRuntime", "Engine", "derive_capacity",
    "bucket_len", "make_trace",
]
