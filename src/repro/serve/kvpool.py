"""Fixed-capacity KV/state slot pool with donated in-place inserts.

The engine allocates ONE pooled cache tree up front —
``model.init_cache(capacity, max_seq)`` — and serves every request out
of a *slot*: one index along each leaf's batch axis.  Requests borrow a
slot at admission and hand it back at retirement; the arrays themselves
are never reallocated, which is exactly the paper's ``noupdate``
residency applied to serving state: the cache buffers are uploaded
(well, allocated) once and stay device-resident for the engine's
lifetime, while per-request traffic is row-sized.

Inserting a freshly prefilled request writes its row into every pooled
leaf with one jitted ``dynamic_update_index_in_dim`` scatter that
**donates** the pooled buffers (``donate_argnums``) — on donating
backends the pool is updated in place, so slot recycling reuses the
same device memory request after request (the leak test asserts both
the slot-index reuse and, where the platform supports donation, the
buffer handoff).

The batch axis of each leaf is *inferred*, not assumed: the pool
eval-shapes ``init_cache`` at two batch sizes and takes the unique axis
whose extent differs.  That keeps the pool agnostic to cache layout —
full KV ``(layers, B, T, K, D)``, Griffin's ``(periods, 2, B, ...)``
recurrent stacks, RWKV's constant-size ``(layers, B, ...)`` state — and
to future cache kinds, as long as decode is row-independent.
"""
from __future__ import annotations

from typing import Any, List, Optional

import numpy as np

__all__ = ["KVSlotPool", "infer_batch_axes", "cache_bytes_per_slot"]


def _diff_axis(sa, sb) -> int:
    """The unique axis where two shapes differ (the batch axis)."""
    if len(sa) != len(sb):
        raise ValueError(f"cache leaf rank changed with batch: {sa} vs {sb}")
    diff = [i for i, (a, b) in enumerate(zip(sa, sb)) if a != b]
    if len(diff) != 1:
        raise ValueError(
            f"cannot infer batch axis from shapes {sa} vs {sb}: "
            f"{len(diff)} axes differ")
    return diff[0]


def infer_batch_axes(model, max_seq: int) -> List[int]:
    """Per-leaf batch-axis index of ``model.init_cache``'s tree, in leaf
    order, found by diffing the abstract shapes at two batch sizes."""
    import jax
    s2 = jax.eval_shape(lambda: model.init_cache(2, max_seq))
    s3 = jax.eval_shape(lambda: model.init_cache(3, max_seq))
    l2, t2 = jax.tree.flatten(s2)
    l3, t3 = jax.tree.flatten(s3)
    if t2 != t3:
        raise ValueError("init_cache tree structure depends on batch size")
    return [_diff_axis(a.shape, b.shape) for a, b in zip(l2, l3)]


def cache_bytes_per_slot(model, max_seq: int) -> int:
    """Device bytes one request's slot owns (all leaves, batch=1) — the
    per-sequence unit of the engine's device-bytes budget."""
    import jax
    shapes = jax.eval_shape(lambda: model.init_cache(1, max_seq))
    return sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(shapes))


class KVSlotPool:
    """Slot allocator + owner of the pooled cache tree.

    Free slots are recycled LIFO so a just-retired slot is the next one
    handed out — the access pattern donation rewards (the freed row's
    buffers are hottest).  ``alloc`` returns ``None`` when exhausted
    (the admission queue waits; nothing OOMs), ``free`` asserts against
    double-free, and ``assert_no_leaks`` is the engine-shutdown check
    that every borrowed slot came back.
    """

    def __init__(self, model, capacity: int, max_seq: int):
        if capacity < 1:
            raise ValueError("pool capacity must be >= 1")
        self.capacity = int(capacity)
        self.max_seq = int(max_seq)
        self.batch_axes = infer_batch_axes(model, max_seq)
        self.cache = model.init_cache(capacity, max_seq)
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._in_use: set = set()
        self.allocs = 0
        self.frees = 0
        self.peak_in_use = 0
        self.reused_slots = 0          # allocs that recycled a freed slot
        self._ever_used: set = set()

    # -- slot bookkeeping ----------------------------------------------------
    @property
    def in_use(self) -> int:
        return len(self._in_use)

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._in_use.add(slot)
        self.allocs += 1
        if slot in self._ever_used:
            self.reused_slots += 1
        self._ever_used.add(slot)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise RuntimeError(f"double free / foreign slot {slot}")
        self._in_use.remove(slot)
        self._free.append(slot)       # LIFO: next alloc reuses it
        self.frees += 1

    def assert_no_leaks(self) -> None:
        if self._in_use:
            raise RuntimeError(
                f"KV slot leak: {sorted(self._in_use)} still allocated "
                f"({self.allocs} allocs / {self.frees} frees)")
        assert self.free_count == self.capacity, (
            self.free_count, self.capacity)

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "in_use": self.in_use,
            "peak_in_use": self.peak_in_use,
            "allocs": self.allocs,
            "frees": self.frees,
            "reused_slots": self.reused_slots,
        }

    # -- pooled-cache insert -------------------------------------------------
    def insert(self, new_cache: Any, src_idx: int, slot: int) -> None:
        """Scatter row ``src_idx`` of ``new_cache`` (a prefill-produced
        cache tree, any batch size) into pooled row ``slot``, donating
        the pooled buffers.  One jitted dispatch for the whole tree."""
        import jax
        if slot not in self._in_use:
            raise RuntimeError(f"insert into unallocated slot {slot}")
        pool_leaves, treedef = jax.tree.flatten(self.cache)
        new_leaves, new_def = jax.tree.flatten(new_cache)
        if new_def != treedef:
            raise ValueError(
                f"prefill cache tree {new_def} != pool tree {treedef}")
        out = _insert_fn(tuple(self.batch_axes))(
            tuple(pool_leaves), tuple(new_leaves), src_idx, slot)
        self.cache = jax.tree.unflatten(treedef, out)


def _insert_fn(axes: tuple):
    """Jitted per-leaf row scatter, shared by every pool with the same
    batch-axis layout — a fresh pool (new engine, new benchmark mode)
    must not recompile it."""
    fn = _INSERT_FNS.get(axes)
    if fn is None:
        import functools

        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def insert(pool_leaves, new_leaves, src_idx, slot):
            out = []
            for pl, nl, ax in zip(pool_leaves, new_leaves, axes):
                row = jax.lax.dynamic_index_in_dim(nl, src_idx, ax,
                                                   keepdims=False)
                out.append(jax.lax.dynamic_update_index_in_dim(
                    pl, row.astype(pl.dtype), slot, ax))
            return tuple(out)

        fn = _INSERT_FNS.setdefault(axes, insert)
    return fn


_INSERT_FNS: dict = {}
