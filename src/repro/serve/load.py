"""Seeded synthetic load: open-loop Poisson arrivals with mixed lengths.

Open-loop means arrival times are drawn up front and do NOT react to
engine backpressure — the realistic regime for a serving benchmark
(clients don't slow down because the server is busy).  Everything is
driven by one ``numpy`` Generator, so a (seed, rate, mixes) tuple is a
reproducible trace: the continuous and static benchmark modes replay
the IDENTICAL request sequence.

The default generation-length mix is deliberately skewed (mostly short,
a few long): that is the traffic shape where continuous batching wins —
under static batching every group drains at the pace of its longest
member, while continuous batching backfills the freed rows.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .request import Request

__all__ = ["make_trace", "DEFAULT_GEN_MIX", "DEFAULT_PROMPT_MIX"]

# (length, probability) pairs; probabilities are normalized
DEFAULT_PROMPT_MIX: Sequence = ((8, 0.5), (16, 0.35), (24, 0.15))
DEFAULT_GEN_MIX: Sequence = ((4, 0.55), (8, 0.30), (48, 0.15))


def _draw(rng: np.random.Generator, mix: Sequence, n: int) -> np.ndarray:
    vals = np.array([v for v, _ in mix], np.int64)
    p = np.array([w for _, w in mix], np.float64)
    return rng.choice(vals, size=n, p=p / p.sum())


def make_trace(cfg, *, n_requests: int, rate_rps: float, seed: int = 0,
               prompt_mix: Sequence = DEFAULT_PROMPT_MIX,
               gen_mix: Sequence = DEFAULT_GEN_MIX,
               max_seq: Optional[int] = None) -> List[Request]:
    """Build ``n_requests`` requests with Exp(1/rate) inter-arrival gaps
    (i.e. Poisson arrivals at ``rate_rps``).  Prompts are random tokens in
    ``cfg.vocab`` — or random embeds for ``input_embeds`` archs.  When
    ``max_seq`` is given, drawn lengths are clamped so every request fits."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-9), n_requests)
    arrivals = np.cumsum(gaps)
    plens = _draw(rng, prompt_mix, n_requests)
    glens = _draw(rng, gen_mix, n_requests)
    if max_seq is not None:
        plens = np.minimum(plens, max_seq - 1)
        glens = np.minimum(glens, max_seq - plens)
    out: List[Request] = []
    for rid in range(n_requests):
        L = int(plens[rid])
        if cfg.input_embeds:
            prompt = rng.standard_normal((L, cfg.d_model)).astype(np.float32)
        else:
            prompt = rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
        out.append(Request(rid=rid, prompt=prompt,
                           max_new_tokens=int(glens[rid]),
                           arrival_s=float(arrivals[rid])))
    return out
