"""Request lifecycle for the continuous-batching serving engine.

A ``Request`` is one user's generation job: a prompt (tokens, or embeds
for frontend-stub archs) plus a token budget.  Its life is a strict
state machine —

    QUEUED ──admit──▶ PREFILLING ──insert──▶ DECODING ──last token──▶ FINISHED

mirroring the paper's residency policy at request granularity: admission
triggers the prompt upload + prefill (advancedload of the request's
only bulk input), decoding moves nothing but the per-step token, and the
generated tokens are fetched back in one lazy batched download when the
request retires (delegatestore).

Timestamps are recorded at every transition so the load generator can
report end-to-end latency (``t_finish - arrival_s``), queueing delay,
and time-to-first-token without instrumenting the engine.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestState"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


_LEGAL = {
    RequestState.QUEUED: (RequestState.PREFILLING,),
    RequestState.PREFILLING: (RequestState.DECODING,),
    RequestState.DECODING: (RequestState.FINISHED,),
    RequestState.FINISHED: (),
}


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array — or a (L, d_model) float
    array for ``input_embeds`` archs.  ``max_new_tokens`` counts the
    prefill's first sampled token, matching ``launch.serve``'s ``gen``.
    """
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_s: float = 0.0

    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    tokens: Optional[np.ndarray] = None   # filled at retirement
    t_admit: Optional[float] = None       # QUEUED -> PREFILLING
    t_first_token: Optional[float] = None  # PREFILLING -> DECODING
    t_finish: Optional[float] = None      # DECODING -> FINISHED

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt)
        if self.prompt.ndim not in (1, 2) or self.prompt.shape[0] < 1:
            raise ValueError(
                f"request {self.rid}: prompt must be (L,) tokens or "
                f"(L, d) embeds with L >= 1, got {self.prompt.shape}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        """Prompt + generation budget — the admission-queue unit for the
        max-batch-tokens budget (every admitted token eventually owns a
        KV/state slot position)."""
        return self.prompt_len + self.max_new_tokens

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_finish is None:
            return None
        return self.t_finish - self.arrival_s

    # -- transitions --------------------------------------------------------
    def _to(self, new: RequestState) -> None:
        if new not in _LEGAL[self.state]:
            raise RuntimeError(
                f"request {self.rid}: illegal transition "
                f"{self.state.value} -> {new.value}")
        self.state = new

    def to_prefilling(self, now: float) -> None:
        self._to(RequestState.PREFILLING)
        self.t_admit = now

    def to_decoding(self, slot: int, now: float) -> None:
        self._to(RequestState.DECODING)
        self.slot = slot
        self.t_first_token = now

    def to_finished(self, now: float) -> None:
        self._to(RequestState.FINISHED)
        self.t_finish = now

    def retire(self, tokens: np.ndarray) -> None:
        """Attach the fetched generation (called at the lazy batched
        download, after ``to_finished``)."""
        assert self.state is RequestState.FINISHED, self.state
        assert tokens.shape[0] == self.max_new_tokens, (
            tokens.shape, self.max_new_tokens)
        self.tokens = np.asarray(tokens)

    def record(self) -> dict:
        """JSON-friendly per-request metrics row."""
        return {
            "rid": self.rid,
            "prompt_len": self.prompt_len,
            "max_new_tokens": self.max_new_tokens,
            "arrival_s": self.arrival_s,
            "t_admit": self.t_admit,
            "t_first_token": self.t_first_token,
            "t_finish": self.t_finish,
            "latency_s": self.latency_s,
            "state": self.state.value,
        }
