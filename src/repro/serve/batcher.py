"""Continuous batcher: host-side occupancy bookkeeping for the decode batch.

The device steps a FIXED-capacity padded decode batch every iteration;
this class tracks which rows are live, how many tokens each owes, and
when a row finishes — all with plain Python counters, so the decode loop
never downloads anything per step (the engine fetches generated tokens
lazily, in batches, at retirement).

Two join policies:

``continuous``
    a freed / free slot may be (re)filled at ANY step boundary — the
    decode batch stays occupied and short requests never wait out long
    ones (no head-of-line blocking);
``static``
    the legacy batch-serving discipline used as the benchmark baseline:
    new requests may only join when the batch has fully drained, so
    every group runs to its slowest member.
"""
from __future__ import annotations

from typing import Dict, List

from .request import Request

__all__ = ["ContinuousBatcher", "JOIN_POLICIES"]

JOIN_POLICIES = ("continuous", "static")


class ContinuousBatcher:
    def __init__(self, join_policy: str = "continuous"):
        if join_policy not in JOIN_POLICIES:
            raise ValueError(
                f"unknown join policy {join_policy!r}; have {JOIN_POLICIES}")
        self.join_policy = join_policy
        self.active: Dict[int, Request] = {}       # slot -> request
        self._remaining: Dict[int, int] = {}       # slot -> tokens still owed
        self.steps = 0
        self.occupied_row_steps = 0   # sum over steps of live rows

    def __len__(self) -> int:
        return len(self.active)

    @property
    def tokens_in_flight(self) -> int:
        """Admission-budget units currently held by live rows."""
        return sum(r.total_tokens for r in self.active.values())

    def can_join(self) -> bool:
        if self.join_policy == "static":
            return not self.active
        return True

    def join(self, req: Request, slot: int) -> None:
        """Account an admitted request.  The prefill already produced its
        first token, so the row owes ``max_new_tokens - 1`` decode steps
        (a gen=1 request finishes without ever decoding)."""
        assert slot not in self.active, slot
        self.active[slot] = req
        self._remaining[slot] = req.max_new_tokens - 1

    def finished_now(self) -> List[int]:
        """Slots that owe zero further tokens (gen=1 admissions)."""
        return [s for s, n in self._remaining.items() if n <= 0]

    def step(self) -> List[int]:
        """Account one decode step over every live row; returns the slots
        that just produced their final token."""
        self.steps += 1
        self.occupied_row_steps += len(self.active)
        done = []
        for slot in self.active:
            self._remaining[slot] -= 1
            if self._remaining[slot] == 0:
                done.append(slot)
        return done

    def leave(self, slot: int) -> Request:
        """Detach a finished row (its slot goes back to the pool)."""
        req = self.active.pop(slot)
        del self._remaining[slot]
        return req

    def occupancy(self, capacity: int) -> float:
        """Mean fraction of the padded batch doing useful work."""
        if self.steps == 0:
            return 0.0
        return self.occupied_row_steps / (self.steps * capacity)
