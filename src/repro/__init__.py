"""repro — OMP2HMPP (Saà-Garriga et al., 2014) as a JAX/TPU framework.

The paper's transfer-directive optimization (advancedload/delegatestore/
noupdate/group/async+sync placement from static dataflow analysis) is
implemented in ``repro.core`` and integrated as a first-class feature of a
multi-pod training/serving stack (``repro.models``, ``repro.distributed``,
``repro.optim``, ``repro.checkpoint``, ``repro.launch``).
"""
__version__ = "1.0.0"
