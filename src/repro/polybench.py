"""Polybench problems as offload block-programs — the paper's workloads.

Each builder mirrors the paper's C structure: host init loops, one or more
``#pragma omp parallel for target cuda`` blocks (→ ``Program.offload``),
host consumption of results.  The 3MM builder reproduces the paper's
Tables 1-2 worked example; the full set backs Fig. 6's speedup comparison
(benchmarks/transfer_polybench.py).

Every builder returns (Program, dict of input arrays).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import Program

__all__ = ["build", "PROBLEMS"]


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def build_3mm(n: int = 512, iters: int = 1, seed: int = 0
              ) -> Tuple[Program, Dict[str, np.ndarray]]:
    """E := A·B;  F := C·D;  G := E·F  (paper Table 1/2)."""
    rng = np.random.default_rng(seed)
    p = Program("3mm")
    for nm in "ABCD":
        p.bind(nm, _rand(rng, n, n))
    p.offload(lambda xp, A, B: {"E": A @ B}, reads=("A", "B"),
              writes=("E",), name="mm_E")
    p.offload(lambda xp, C, D: {"F": C @ D}, reads=("C", "D"),
              writes=("F",), name="mm_F")
    p.offload(lambda xp, E, F: {"G": E @ F}, reads=("E", "F"),
              writes=("G",), name="mm_G")
    p.host(lambda xp, G: {"out": G.sum(axis=0, keepdims=True)},
           reads=("G",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_2mm(n: int = 512, iters: int = 1, seed: int = 0):
    """D := alpha·A·B·C + beta·D."""
    rng = np.random.default_rng(seed)
    p = Program("2mm")
    for nm in ("A", "B", "C", "D"):
        p.bind(nm, _rand(rng, n, n))
    p.offload(lambda xp, A, B: {"tmp": 1.5 * (A @ B)},
              reads=("A", "B"), writes=("tmp",), name="mm1")
    p.offload(lambda xp, tmp, C, D: {"D": tmp @ C + 1.2 * D},
              reads=("tmp", "C", "D"), writes=("D",), name="mm2")
    p.host(lambda xp, D: {"out": D.sum(axis=0, keepdims=True)},
           reads=("D",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_gemm(n: int = 768, iters: int = 4, seed: int = 0):
    """Repeated C := alpha·A·B + beta·C inside a host-visible loop — the
    loop residency case (C stays on device across iterations)."""
    rng = np.random.default_rng(seed)
    p = Program("gemm")
    p.bind("A", _rand(rng, n, n))
    p.bind("B", _rand(rng, n, n))
    p.bind("C", _rand(rng, n, n))
    with p.loop(iters):
        p.offload(lambda xp, A, B, C: {"C": 0.5 * (A @ B) + 0.9 * C},
                  reads=("A", "B", "C"), writes=("C",), name="gemm")
    p.host(lambda xp, C: {"out": C.sum(axis=0, keepdims=True)},
           reads=("C",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_atax(n: int = 2048, iters: int = 1, seed: int = 0):
    """y := Aᵀ·(A·x)."""
    rng = np.random.default_rng(seed)
    p = Program("atax")
    p.bind("A", _rand(rng, n, n))
    p.bind("x", _rand(rng, n))
    p.offload(lambda xp, A, x: {"tmp": A @ x}, reads=("A", "x"),
              writes=("tmp",), name="Ax")
    p.offload(lambda xp, A, tmp: {"y": A.T @ tmp}, reads=("A", "tmp"),
              writes=("y",), name="ATtmp")
    p.host(lambda xp, y: {"out": y[:8]}, reads=("y",), writes=("out",),
           name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_bicg(n: int = 2048, iters: int = 1, seed: int = 0):
    """s := Aᵀ·r;  q := A·p."""
    rng = np.random.default_rng(seed)
    p = Program("bicg")
    p.bind("A", _rand(rng, n, n))
    p.bind("r", _rand(rng, n))
    p.bind("pv", _rand(rng, n))
    p.offload(lambda xp, A, r: {"s": A.T @ r}, reads=("A", "r"),
              writes=("s",), name="ATr")
    p.offload(lambda xp, A, pv: {"q": A @ pv}, reads=("A", "pv"),
              writes=("q",), name="Ap")
    p.host(lambda xp, s, q: {"out": s[:4] + q[:4]}, reads=("s", "q"),
           writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_mvt(n: int = 2048, iters: int = 1, seed: int = 0):
    rng = np.random.default_rng(seed)
    p = Program("mvt")
    p.bind("A", _rand(rng, n, n))
    p.bind("x1", _rand(rng, n))
    p.bind("x2", _rand(rng, n))
    p.bind("y1", _rand(rng, n))
    p.bind("y2", _rand(rng, n))
    p.offload(lambda xp, A, x1, y1: {"x1": x1 + A @ y1},
              reads=("A", "x1", "y1"), writes=("x1",), name="mvt1")
    p.offload(lambda xp, A, x2, y2: {"x2": x2 + A.T @ y2},
              reads=("A", "x2", "y2"), writes=("x2",), name="mvt2")
    p.host(lambda xp, x1, x2: {"out": x1[:4] + x2[:4]},
           reads=("x1", "x2"), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_gesummv(n: int = 1536, iters: int = 1, seed: int = 0):
    rng = np.random.default_rng(seed)
    p = Program("gesummv")
    p.bind("A", _rand(rng, n, n))
    p.bind("B", _rand(rng, n, n))
    p.bind("x", _rand(rng, n))
    p.offload(lambda xp, A, B, x: {"y": 1.1 * (A @ x) + 0.9 * (B @ x)},
              reads=("A", "B", "x"), writes=("y",), name="gesummv")
    p.host(lambda xp, y: {"out": y[:8]}, reads=("y",), writes=("out",),
           name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_syrk(n: int = 640, iters: int = 2, seed: int = 0):
    """C := alpha·A·Aᵀ + beta·C, iterated."""
    rng = np.random.default_rng(seed)
    p = Program("syrk")
    p.bind("A", _rand(rng, n, n))
    p.bind("C", _rand(rng, n, n))
    with p.loop(iters):
        p.offload(lambda xp, A, C: {"C": 0.1 * (A @ A.T) + 0.9 * C},
                  reads=("A", "C"), writes=("C",), name="syrk")
    p.host(lambda xp, C: {"out": C.sum(axis=0, keepdims=True)},
           reads=("C",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_covariance(n: int = 768, iters: int = 1, seed: int = 0):
    """The paper's best case (near hand-CUDA): mean, center, cov."""
    rng = np.random.default_rng(seed)
    p = Program("covariance")
    p.bind("data", _rand(rng, n, n))
    p.offload(lambda xp, data: {"mean": data.mean(axis=0, keepdims=True)},
              reads=("data",), writes=("mean",), name="mean")
    p.offload(lambda xp, data, mean: {"cent": data - mean},
              reads=("data", "mean"), writes=("cent",), name="center")
    p.offload(lambda xp, cent: {"cov": cent.T @ cent / (cent.shape[0] - 1)},
              reads=("cent",), writes=("cov",), name="cov")
    p.host(lambda xp, cov: {"out": cov.sum(axis=0, keepdims=True)},
           reads=("cov",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


def build_jacobi2d(n: int = 1024, iters: int = 20, seed: int = 0):
    """Stencil iterated on device — residency across a long loop; host
    samples the field every iteration chunk."""
    rng = np.random.default_rng(seed)
    p = Program("jacobi2d")
    p.bind("U", _rand(rng, n, n))

    def jacobi(xp, U):
        inner = 0.2 * (U[1:-1, 1:-1] + U[:-2, 1:-1] + U[2:, 1:-1]
                       + U[1:-1, :-2] + U[1:-1, 2:])
        if xp is np:
            out = U.copy()
        else:
            out = U
        out = xp.asarray(out)
        # functional update for jax / numpy parity
        out = xp.concatenate([
            U[:1],
            xp.concatenate([U[1:-1, :1], inner, U[1:-1, -1:]], axis=1),
            U[-1:],
        ], axis=0)
        return {"U": out}

    with p.loop(iters):
        p.offload(jacobi, reads=("U",), writes=("U",), name="jacobi")
    p.host(lambda xp, U: {"out": U.sum(axis=0, keepdims=True)},
           reads=("U",), writes=("out",), name="consume")
    p.set_outputs("out")
    return p, dict(p.inputs)


PROBLEMS = {
    "2mm": build_2mm,
    "3mm": build_3mm,
    "gemm": build_gemm,
    "atax": build_atax,
    "bicg": build_bicg,
    "mvt": build_mvt,
    "gesummv": build_gesummv,
    "syrk": build_syrk,
    "covariance": build_covariance,
    "jacobi2d": build_jacobi2d,
}


def build(name: str, **kw):
    return PROBLEMS[name](**kw)
